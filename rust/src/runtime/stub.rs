//! API-compatible stand-in for the PJRT artifact runtime, compiled when
//! the `xla-runtime` feature is off (the default). Every constructor
//! fails with guidance, so callers — the `runtime` subcommand, the
//! `vr_session` example, `tests/runtime_parity.rs` — compile unchanged
//! and skip gracefully at runtime.

use std::path::Path;

use anyhow::Result;

use super::{unavailable, ManifestConstants, TileCarry};
use crate::constants::SH_COEFFS;

/// Stub artifact registry: construction always fails (see module docs).
pub struct ArtifactRuntime {
    /// Kept for API parity with the PJRT-backed runtime.
    pub manifest_constants: ManifestConstants,
}

impl ArtifactRuntime {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
        unavailable()
    }

    /// Artifact directory this runtime loaded from.
    pub fn dir(&self) -> &Path {
        Path::new("")
    }

    /// Names of loaded artifacts.
    pub fn artifact_names(&self) -> Vec<&str> {
        Vec::new()
    }

    /// PJRT platform string (for logs).
    pub fn platform(&self) -> String {
        "unavailable (built without xla-runtime)".to_string()
    }

    /// See the `xla-runtime` implementation.
    #[allow(clippy::too_many_arguments)]
    pub fn raster_tile_chunk(
        &self,
        _means: &[[f32; 2]],
        _conics: &[[f32; 3]],
        _opacs: &[f32],
        _colors: &[[f32; 3]],
        _origin: [f32; 2],
        _carry: &TileCarry,
    ) -> Result<TileCarry> {
        unavailable()
    }

    /// See the `xla-runtime` implementation.
    pub fn sh_eval_chunk(
        &self,
        _dirs: &[[f32; 3]],
        _coeffs: &[[[f32; 3]; SH_COEFFS]],
    ) -> Result<Vec<[f32; 3]>> {
        unavailable()
    }

    /// See the `xla-runtime` implementation.
    pub fn alpha_front_chunk(
        &self,
        _means: &[[f32; 2]],
        _conics: &[[f32; 3]],
        _opacs: &[f32],
        _origin: [f32; 2],
    ) -> Result<Vec<f32>> {
        unavailable()
    }

    /// See the `xla-runtime` implementation.
    pub fn raster_tile_full(
        &self,
        _means: &[[f32; 2]],
        _conics: &[[f32; 3]],
        _opacs: &[f32],
        _colors: &[[f32; 3]],
        _origin: [f32; 2],
    ) -> Result<TileCarry> {
        unavailable()
    }
}
