//! Type-level stub of the `xla` crate surface `pjrt.rs` uses.
//!
//! The vendored `xla` crate (xla_extension 0.5.1 native libraries) is
//! not part of the offline crate set, so the real dependency stays
//! commented out in `Cargo.toml`. This module lets
//! `cargo check --features xla-runtime` type-check the whole PJRT path
//! anyway — the CI feature-matrix step that keeps `runtime/pjrt.rs`
//! from bit-rotting while `tests/runtime_parity.rs` skips. Every entry
//! point fails at runtime with the same guidance as
//! [`super::stub`]; builds with the real crate enable the
//! `xla-vendored` feature, which routes `pjrt.rs` back to the genuine
//! `xla` paths and compiles this module out.

use std::fmt;

/// Error carrying the not-vendored guidance.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "the xla crate is not vendored in this build: the xla-runtime feature \
         type-checks the PJRT path against an API stub; add the vendored `xla` \
         dependency to Cargo.toml and build with --features xla-vendored to \
         actually execute artifacts"
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}
