//! LGSC binary scene IO — the format shared with `python/compile/common.py`.
//!
//! Layout (little-endian):
//! `magic "LGSC" | version u32 | count u32 | sh_degree u32 |`
//! `pos f32[N,3] | scale f32[N,3] | quat f32[N,4] (w,x,y,z) |`
//! `opacity f32[N] | sh f32[N,16,3]`.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::GaussianScene;
use crate::constants::SH_COEFFS;
use crate::math::{Quat, Vec3};

const MAGIC: &[u8; 4] = b"LGSC";
const VERSION: u32 = 1;

/// Write a scene to an LGSC file.
pub fn write_scene(path: impl AsRef<Path>, scene: &GaussianScene) -> Result<()> {
    scene.validate().map_err(|e| anyhow::anyhow!(e))?;
    let f = File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(scene.len() as u32).to_le_bytes())?;
    w.write_all(&3u32.to_le_bytes())?;
    for p in &scene.pos {
        write_f32s(&mut w, &[p.x, p.y, p.z])?;
    }
    for s in &scene.scale {
        write_f32s(&mut w, &[s.x, s.y, s.z])?;
    }
    for q in &scene.quat {
        write_f32s(&mut w, &[q.w, q.x, q.y, q.z])?;
    }
    for o in &scene.opacity {
        write_f32s(&mut w, &[*o])?;
    }
    for sh in &scene.sh {
        for coeff in sh {
            write_f32s(&mut w, coeff)?;
        }
    }
    Ok(())
}

/// Read a scene from an LGSC file.
pub fn read_scene(path: impl AsRef<Path>) -> Result<GaussianScene> {
    let f = File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad scene magic {:?}", magic);
    }
    let version = read_u32(&mut r)?;
    ensure!(version == VERSION, "unsupported scene version {version}");
    let n = read_u32(&mut r)? as usize;
    let sh_deg = read_u32(&mut r)?;
    ensure!(sh_deg == 3, "unsupported sh degree {sh_deg}");

    let mut scene = GaussianScene::with_capacity(n);
    let mut buf = vec![0f32; n * 3];
    read_f32s(&mut r, &mut buf)?;
    for c in buf.chunks_exact(3) {
        scene.pos.push(Vec3::new(c[0], c[1], c[2]));
    }
    read_f32s(&mut r, &mut buf)?;
    for c in buf.chunks_exact(3) {
        scene.scale.push(Vec3::new(c[0], c[1], c[2]));
    }
    let mut qbuf = vec![0f32; n * 4];
    read_f32s(&mut r, &mut qbuf)?;
    for c in qbuf.chunks_exact(4) {
        scene.quat.push(Quat::new(c[0], c[1], c[2], c[3]));
    }
    let mut obuf = vec![0f32; n];
    read_f32s(&mut r, &mut obuf)?;
    scene.opacity = obuf;
    let mut shbuf = vec![0f32; n * SH_COEFFS * 3];
    read_f32s(&mut r, &mut shbuf)?;
    for g in shbuf.chunks_exact(SH_COEFFS * 3) {
        let mut sh = [[0f32; 3]; SH_COEFFS];
        for (k, coeff) in g.chunks_exact(3).enumerate() {
            sh[k] = [coeff[0], coeff[1], coeff[2]];
        }
        scene.sh.push(sh);
    }
    scene.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(scene)
}

fn write_f32s<W: Write>(w: &mut W, vals: &[f32]) -> Result<()> {
    for v in vals {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut bytes = vec![0u8; out.len() * 4];
    r.read_exact(&mut bytes)?;
    for (v, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::synth::{synth_scene, SceneClass};

    use crate::util::testing::TempPath;

    #[test]
    fn roundtrip() {
        let scene = synth_scene(SceneClass::SyntheticSmall, 123, 500);
        let dir = TempPath::dir();
        let path = dir.path.join("s.lgsc");
        write_scene(&path, &scene).unwrap();
        let got = read_scene(&path).unwrap();
        assert_eq!(got.len(), scene.len());
        for i in 0..scene.len() {
            assert_eq!(got.pos[i], scene.pos[i]);
            assert_eq!(got.scale[i], scene.scale[i]);
            assert_eq!(got.quat[i], scene.quat[i]);
            assert_eq!(got.opacity[i], scene.opacity[i]);
            assert_eq!(got.sh[i], scene.sh[i]);
        }
    }

    #[test]
    fn rejects_garbage() {
        let dir = TempPath::dir();
        let path = dir.path.join("bad.lgsc");
        std::fs::write(&path, b"XXXXnotascene").unwrap();
        assert!(read_scene(&path).is_err());
    }
}
