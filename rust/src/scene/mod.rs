//! Gaussian scene representation: the substrate every pipeline stage reads.
//!
//! Structure-of-arrays layout for cache-friendly streaming, matching the
//! LGSC binary format shared with the Python build path (`scene/io.rs`).

pub mod io;
pub mod sh;
pub mod synth;

use crate::constants::SH_COEFFS;
use crate::math::{Quat, Vec3};

/// A 3D Gaussian scene in SoA layout.
///
/// Invariants: all vectors have identical length `len()`; `opacity` is
/// post-sigmoid in `[0, 1]`; `scale` is linear (not log); quaternions need
/// not be normalized (consumers normalize).
#[derive(Debug, Clone, Default)]
pub struct GaussianScene {
    /// World-space centers.
    pub pos: Vec<Vec3>,
    /// Per-axis standard deviations of the 3D Gaussian.
    pub scale: Vec<Vec3>,
    /// Orientation quaternions (w, x, y, z).
    pub quat: Vec<Quat>,
    /// Opacity in [0, 1] (already sigmoid-activated).
    pub opacity: Vec<f32>,
    /// Degree-3 SH coefficients, RGB-interleaved: [coeff][channel].
    pub sh: Vec<[[f32; 3]; SH_COEFFS]>,
}

impl GaussianScene {
    /// Number of Gaussians.
    pub fn len(&self) -> usize {
        self.pos.len()
    }

    /// True when the scene holds no Gaussians.
    pub fn is_empty(&self) -> bool {
        self.pos.is_empty()
    }

    /// Allocate an empty scene with capacity for `n` Gaussians.
    pub fn with_capacity(n: usize) -> Self {
        GaussianScene {
            pos: Vec::with_capacity(n),
            scale: Vec::with_capacity(n),
            quat: Vec::with_capacity(n),
            opacity: Vec::with_capacity(n),
            sh: Vec::with_capacity(n),
        }
    }

    /// Append one Gaussian.
    pub fn push(
        &mut self,
        pos: Vec3,
        scale: Vec3,
        quat: Quat,
        opacity: f32,
        sh: [[f32; 3]; SH_COEFFS],
    ) {
        self.pos.push(pos);
        self.scale.push(scale);
        self.quat.push(quat);
        self.opacity.push(opacity);
        self.sh.push(sh);
    }

    /// Check the SoA invariant (equal lengths); used by IO and tests.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.pos.len();
        let ok = self.scale.len() == n
            && self.quat.len() == n
            && self.opacity.len() == n
            && self.sh.len() == n;
        if !ok {
            return Err(format!(
                "SoA length mismatch: pos={} scale={} quat={} opacity={} sh={}",
                n,
                self.scale.len(),
                self.quat.len(),
                self.opacity.len(),
                self.sh.len()
            ));
        }
        for (i, o) in self.opacity.iter().enumerate() {
            if !(0.0..=1.0).contains(o) || !o.is_finite() {
                return Err(format!("opacity[{i}] = {o} outside [0,1]"));
            }
        }
        Ok(())
    }

    /// Geometric mean of the three scale parameters of Gaussian `i`
    /// (the `S` in the paper's scale-constrained loss, Eqn. 4).
    pub fn geo_mean_scale(&self, i: usize) -> f32 {
        let s = self.scale[i];
        (s.x * s.y * s.z).abs().powf(1.0 / 3.0)
    }

    /// The first `n` Gaussians as an owned scene — the reduced-Gaussian
    /// LoD tier's subsample. Synthetic scenes draw every attribute
    /// independently per index, so a prefix is an unbiased random
    /// subsample; Gaussian indices (and therefore radiance-cache tag
    /// IDs) are preserved.
    pub fn prefix(&self, n: usize) -> GaussianScene {
        let n = n.min(self.len());
        GaussianScene {
            pos: self.pos[..n].to_vec(),
            scale: self.scale[..n].to_vec(),
            quat: self.quat[..n].to_vec(),
            opacity: self.opacity[..n].to_vec(),
            sh: self.sh[..n].to_vec(),
        }
    }

    /// The reduced serving tier's subsample: a `fraction` prefix
    /// (rounded, at least one Gaussian). The single place the
    /// fraction-to-count policy lives, so a standalone coordinator and
    /// a pooled session always cut the identical subsample.
    pub fn reduced_prefix(&self, fraction: f64) -> GaussianScene {
        let n = ((self.len() as f64 * fraction).round() as usize).clamp(1, self.len());
        self.prefix(n)
    }

    /// Axis-aligned bounding box of all centers.
    pub fn bounds(&self) -> (Vec3, Vec3) {
        let mut lo = Vec3::new(f32::INFINITY, f32::INFINITY, f32::INFINITY);
        let mut hi = Vec3::new(f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY);
        for p in &self.pos {
            lo = Vec3::new(lo.x.min(p.x), lo.y.min(p.y), lo.z.min(p.z));
            hi = Vec3::new(hi.x.max(p.x), hi.y.max(p.y), hi.z.max(p.z));
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_validate() {
        let mut s = GaussianScene::with_capacity(2);
        s.push(
            Vec3::new(0.0, 1.0, 2.0),
            Vec3::new(0.1, 0.1, 0.1),
            Quat::IDENTITY,
            0.5,
            [[0.0; 3]; SH_COEFFS],
        );
        assert_eq!(s.len(), 1);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn prefix_truncates_and_preserves_order() {
        let mut s = GaussianScene::default();
        for i in 0..5 {
            s.push(
                Vec3::new(i as f32, 0.0, 0.0),
                Vec3::new(0.1, 0.1, 0.1),
                Quat::IDENTITY,
                0.5,
                [[0.0; 3]; SH_COEFFS],
            );
        }
        let p = s.prefix(3);
        assert_eq!(p.len(), 3);
        assert!(p.validate().is_ok());
        assert_eq!(p.pos[2].x, 2.0);
        // Oversized requests clamp.
        assert_eq!(s.prefix(99).len(), 5);
    }

    #[test]
    fn validate_rejects_bad_opacity() {
        let mut s = GaussianScene::default();
        s.push(Vec3::ZERO, Vec3::ZERO, Quat::IDENTITY, 1.5, [[0.0; 3]; SH_COEFFS]);
        assert!(s.validate().is_err());
    }

    #[test]
    fn geo_mean_scale() {
        let mut s = GaussianScene::default();
        s.push(
            Vec3::ZERO,
            Vec3::new(1.0, 8.0, 1.0),
            Quat::IDENTITY,
            0.5,
            [[0.0; 3]; SH_COEFFS],
        );
        assert!((s.geo_mean_scale(0) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn bounds() {
        let mut s = GaussianScene::default();
        s.push(Vec3::new(-1.0, 0.0, 2.0), Vec3::ZERO, Quat::IDENTITY, 0.1, [[0.0; 3]; SH_COEFFS]);
        s.push(Vec3::new(3.0, -2.0, 1.0), Vec3::ZERO, Quat::IDENTITY, 0.1, [[0.0; 3]; SH_COEFFS]);
        let (lo, hi) = s.bounds();
        assert_eq!(lo, Vec3::new(-1.0, -2.0, 1.0));
        assert_eq!(hi, Vec3::new(3.0, 0.0, 2.0));
    }
}
