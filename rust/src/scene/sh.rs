//! Degree-3 real spherical-harmonic color evaluation.
//!
//! Mirrors `python/compile/kernels/sh_eval.py` / `ref.py` exactly (same
//! basis constants as the reference 3DGS implementation): RGB = clamp(
//! basis(dir) . coeffs + 0.5, 0, inf). S^2 sorting-shared rendering
//! re-evaluates this every frame at the *current* pose (paper Sec. 3.1).

use crate::constants::SH_COEFFS;
use crate::math::Vec3;

pub const SH_C0: f32 = 0.282_094_8;
pub const SH_C1: f32 = 0.488_602_5;
pub const SH_C2: [f32; 5] = [1.092_548_4, -1.092_548_4, 0.315_391_57, -1.092_548_4, 0.546_274_2];
pub const SH_C3: [f32; 7] = [
    -0.590_043_6,
    2.890_611_4,
    -0.457_045_8,
    0.373_176_33,
    -0.457_045_8,
    1.445_305_7,
    -0.590_043_6,
];

/// Evaluate the 16-element degree-3 SH basis at a unit direction.
#[inline]
pub fn sh_basis(d: Vec3) -> [f32; SH_COEFFS] {
    let (x, y, z) = (d.x, d.y, d.z);
    let (xx, yy, zz) = (x * x, y * y, z * z);
    let (xy, yz, xz) = (x * y, y * z, x * z);
    [
        SH_C0,
        -SH_C1 * y,
        SH_C1 * z,
        -SH_C1 * x,
        SH_C2[0] * xy,
        SH_C2[1] * yz,
        SH_C2[2] * (2.0 * zz - xx - yy),
        SH_C2[3] * xz,
        SH_C2[4] * (xx - yy),
        SH_C3[0] * y * (3.0 * xx - yy),
        SH_C3[1] * xy * z,
        SH_C3[2] * y * (4.0 * zz - xx - yy),
        SH_C3[3] * z * (2.0 * zz - 3.0 * xx - 3.0 * yy),
        SH_C3[4] * x * (4.0 * zz - xx - yy),
        SH_C3[5] * z * (xx - yy),
        SH_C3[6] * x * (xx - 3.0 * yy),
    ]
}

/// View-dependent RGB of one Gaussian: direction from camera center to the
/// Gaussian center, contracted with its SH coefficients.
#[inline]
pub fn eval_color(pos: Vec3, cam_center: Vec3, sh: &[[f32; 3]; SH_COEFFS]) -> [f32; 3] {
    let dir = (pos - cam_center).normalized();
    let basis = sh_basis(dir);
    let mut rgb = [0.5f32; 3];
    for k in 0..SH_COEFFS {
        for c in 0..3 {
            rgb[c] += basis[k] * sh[k][c];
        }
    }
    [rgb[0].max(0.0), rgb[1].max(0.0), rgb[2].max(0.0)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dc_only_is_view_independent() {
        let mut sh = [[0.0f32; 3]; SH_COEFFS];
        sh[0] = [1.0, 2.0, -0.5];
        let pos = Vec3::new(1.0, 0.5, 2.0);
        let c1 = eval_color(pos, Vec3::new(0.0, 0.0, -3.0), &sh);
        let c2 = eval_color(pos, Vec3::new(5.0, 1.0, 0.0), &sh);
        for ch in 0..3 {
            assert!((c1[ch] - c2[ch]).abs() < 1e-6);
        }
        // DC expectation: SH_C0 * coeff + 0.5, clamped at 0.
        assert!((c1[0] - (SH_C0 + 0.5)).abs() < 1e-6);
        assert!((c1[1] - (2.0 * SH_C0 + 0.5)).abs() < 1e-6);
        assert!((c1[2] - (-0.5 * SH_C0 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn clamps_negative() {
        let mut sh = [[0.0f32; 3]; SH_COEFFS];
        sh[0] = [-10.0, -10.0, -10.0];
        let c = eval_color(Vec3::new(0.0, 0.0, 1.0), Vec3::ZERO, &sh);
        assert_eq!(c, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn basis_degree1_flips_with_direction() {
        let b1 = sh_basis(Vec3::new(0.0, 1.0, 0.0));
        let b2 = sh_basis(Vec3::new(0.0, -1.0, 0.0));
        assert!((b1[1] + b2[1]).abs() < 1e-6);
        assert!((b1[1] + SH_C1).abs() < 1e-6);
    }

    #[test]
    fn view_dependence_with_degree1() {
        let mut sh = [[0.0f32; 3]; SH_COEFFS];
        sh[1] = [1.0, 0.0, 0.0]; // y-linear band
        let pos = Vec3::ZERO;
        let from_below = eval_color(pos, Vec3::new(0.0, -2.0, 0.0), &sh);
        let from_above = eval_color(pos, Vec3::new(0.0, 2.0, 0.0), &sh);
        assert!(from_below[0] != from_above[0]);
    }
}
