//! Procedural scene synthesis — the dataset substitute.
//!
//! The paper evaluates on trained 3DGS checkpoints of Synthetic-NeRF,
//! Tanks&Temples, DeepBlending, and MipNeRF-360. We have no checkpoints,
//! but every Lumina mechanism keys off *statistics* of those scenes, not
//! their semantic content (DESIGN.md §8):
//!
//! * Gaussian count per scene class (Fig. 2a: <1M synthetic, up to >6M U360),
//! * a log-normal scale distribution with a heavy tail of large splats,
//! * opacity skewed high (trained scenes converge to mostly-opaque splats),
//! * cluster-structured placement so per-pixel iterated lists reach the
//!   hundreds-to-thousands range while only ~10% of encountered Gaussians
//!   are significant (Fig. 4).
//!
//! The generator targets those statistics with a deterministic ChaCha RNG.

use super::GaussianScene;
use crate::constants::SH_COEFFS;
use crate::math::{Quat, Vec3};
use crate::util::prng::Pcg32;

/// Scene complexity classes mirroring the paper's four datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SceneClass {
    /// Synthetic-NeRF-like: small object, < 1M Gaussians, tight extent.
    SyntheticSmall,
    /// Tanks&Temples-like: real capture, ~1-3M Gaussians.
    RealMedium,
    /// DeepBlending-like: indoor scene, ~2-4M Gaussians.
    RealIndoor,
    /// MipNeRF-360-like: unbounded outdoor, > 4M Gaussians.
    RealUnbounded,
}

impl SceneClass {
    /// Default Gaussian count for full-fidelity runs (paper Fig. 2a).
    pub fn default_count(self) -> usize {
        match self {
            SceneClass::SyntheticSmall => 300_000,
            SceneClass::RealMedium => 1_800_000,
            SceneClass::RealIndoor => 3_000_000,
            SceneClass::RealUnbounded => 6_000_000,
        }
    }

    /// World extent (half-width) of the Gaussian cloud.
    pub fn extent(self) -> f32 {
        match self {
            SceneClass::SyntheticSmall => 1.3,
            SceneClass::RealMedium => 6.0,
            SceneClass::RealIndoor => 5.0,
            SceneClass::RealUnbounded => 14.0,
        }
    }

    /// Number of placement clusters (surface patches).
    fn clusters(self) -> usize {
        match self {
            SceneClass::SyntheticSmall => 48,
            SceneClass::RealMedium => 160,
            SceneClass::RealIndoor => 120,
            SceneClass::RealUnbounded => 320,
        }
    }

    /// Median Gaussian scale relative to extent; trained scenes use
    /// smaller splats for detailed geometry.
    fn scale_median(self) -> f32 {
        // Tuned so the per-pixel significance fraction at harness
        // resolution lands near the paper's ~10% (Fig. 4): trained
        // scenes resolve detail at the pixel scale, so splat footprints
        // must stay a few pixels wide.
        match self {
            SceneClass::SyntheticSmall => 0.008,
            SceneClass::RealMedium => 0.0055,
            SceneClass::RealIndoor => 0.0050,
            SceneClass::RealUnbounded => 0.0045,
        }
    }

    /// All four classes, in paper order.
    pub fn all() -> [SceneClass; 4] {
        [
            SceneClass::SyntheticSmall,
            SceneClass::RealMedium,
            SceneClass::RealIndoor,
            SceneClass::RealUnbounded,
        ]
    }

    /// Paper dataset label the class substitutes for.
    pub fn paper_label(self) -> &'static str {
        match self {
            SceneClass::SyntheticSmall => "S-NeRF",
            SceneClass::RealMedium => "T&T",
            SceneClass::RealIndoor => "DB",
            SceneClass::RealUnbounded => "U360",
        }
    }
}

/// Generate a procedural scene of `count` Gaussians in class `class_`.
///
/// Deterministic in `(class_, seed, count)`.
pub fn synth_scene(class_: SceneClass, seed: u64, count: usize) -> GaussianScene {
    let mut rng = Pcg32::new(seed, class_hash(class_));
    let extent = class_.extent();
    let n_clusters = class_.clusters();

    // Cluster centers on a rough sphere/ellipsoid shell, plus some volume
    // fill: mimics surfaces reconstructed by SfM. Each cluster carries a
    // base albedo — trained scenes have spatially coherent color, which
    // is what makes the paper's ray-similarity approximation (Fig. 12)
    // accurate; random per-Gaussian color would overstate RC error.
    let mut centers = Vec::with_capacity(n_clusters);
    let mut normals = Vec::with_capacity(n_clusters);
    let mut albedos = Vec::with_capacity(n_clusters);
    for _ in 0..n_clusters {
        let dir = random_unit(&mut rng);
        // Volume-filling radial distribution: real captures have geometry
        // at every depth, so a ray crosses many surface patches — that
        // depth complexity is what keeps per-pixel iteration counts high
        // (Fig. 4) and rasterization dominant (Fig. 3).
        let r = extent * (0.25 + 0.70 * rng.f32());
        centers.push(dir * r);
        normals.push(dir);
        albedos.push([
            rng.range_f32(-0.5, 1.4),
            rng.range_f32(-0.5, 1.4),
            rng.range_f32(-0.5, 1.4),
        ]);
    }

    let scale_median = class_.scale_median() * extent;
    let mut scene = GaussianScene::with_capacity(count);
    for _ in 0..count {
        let c = rng.below(n_clusters);
        // Anisotropic placement: spread along the surface patch, thin along
        // the normal.
        let tangent_spread = extent * 0.18;
        let normal_spread = extent * 0.015;
        let n = normals[c];
        let (t1, t2) = tangent_basis(n);
        let p = centers[c]
            + t1 * (gauss(&mut rng) * tangent_spread)
            + t2 * (gauss(&mut rng) * tangent_spread)
            + n * (gauss(&mut rng) * normal_spread);

        // Log-normal scales, slightly anisotropic (surfel-like), with a
        // heavy tail: ~2% oversized Gaussians (the Fig. 13 failure mode).
        let base = scale_median * (gauss(&mut rng) * 0.55).exp();
        let tail = if rng.chance(0.02) { 4.0 + 6.0 * rng.f32() } else { 1.0 };
        let s = Vec3::new(
            base * tail * (0.5 + rng.f32()),
            base * tail * (0.5 + rng.f32()),
            base * tail * (0.15 + 0.3 * rng.f32()), // flat along normal
        );

        let quat = random_quat(&mut rng);

        // Opacity: trained scenes skew opaque; ~35% low-opacity "fuzz"
        // drives the significance sparsity of Fig. 4.
        let opacity = if rng.chance(0.35) {
            rng.range_f32(0.002, 0.05)
        } else {
            rng.range_f32(0.35, 0.995)
        };

        // SH: DC = cluster albedo + small variation (spatially coherent
        // color); higher bands add mild view dependence.
        let mut sh = [[0.0f32; 3]; SH_COEFFS];
        for ch in 0..3 {
            sh[0][ch] = albedos[c][ch] + gauss(&mut rng) * 0.12;
        }
        for coeff in sh.iter_mut().skip(1) {
            for ch in 0..3 {
                coeff[ch] = gauss(&mut rng) * 0.05;
            }
        }

        scene.push(p, s, quat, opacity, sh);
    }
    scene
}

/// Convenience: a small scene for unit tests (fast, deterministic).
pub fn test_scene(seed: u64, count: usize) -> GaussianScene {
    synth_scene(SceneClass::SyntheticSmall, seed, count)
}

fn class_hash(c: SceneClass) -> u64 {
    match c {
        SceneClass::SyntheticSmall => 0x5eed_0001,
        SceneClass::RealMedium => 0x5eed_0002,
        SceneClass::RealIndoor => 0x5eed_0003,
        SceneClass::RealUnbounded => 0x5eed_0004,
    }
}

fn gauss(rng: &mut Pcg32) -> f32 {
    rng.gauss()
}

fn random_unit(rng: &mut Pcg32) -> Vec3 {
    loop {
        let v = Vec3::new(
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
        );
        let n = v.norm();
        if n > 1e-4 && n <= 1.0 {
            return v * (1.0 / n);
        }
    }
}

fn random_quat(rng: &mut Pcg32) -> Quat {
    Quat::new(
        gauss(rng),
        gauss(rng),
        gauss(rng),
        gauss(rng),
    )
    .normalized()
}

fn tangent_basis(n: Vec3) -> (Vec3, Vec3) {
    let helper = if n.x.abs() < 0.9 {
        Vec3::new(1.0, 0.0, 0.0)
    } else {
        Vec3::new(0.0, 1.0, 0.0)
    };
    let t1 = n.cross(helper).normalized();
    let t2 = n.cross(t1).normalized();
    (t1, t2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = synth_scene(SceneClass::SyntheticSmall, 7, 200);
        let b = synth_scene(SceneClass::SyntheticSmall, 7, 200);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.opacity, b.opacity);
    }

    #[test]
    fn seed_changes_scene() {
        let a = synth_scene(SceneClass::SyntheticSmall, 7, 50);
        let b = synth_scene(SceneClass::SyntheticSmall, 8, 50);
        assert_ne!(a.pos, b.pos);
    }

    #[test]
    fn valid_and_sized() {
        for class_ in SceneClass::all() {
            let s = synth_scene(class_, 1, 300);
            assert_eq!(s.len(), 300);
            s.validate().unwrap();
        }
    }

    #[test]
    fn opacity_distribution_is_bimodal() {
        let s = synth_scene(SceneClass::RealMedium, 3, 5000);
        let low = s.opacity.iter().filter(|o| **o < 0.05).count() as f32 / 5000.0;
        let high = s.opacity.iter().filter(|o| **o > 0.35).count() as f32 / 5000.0;
        assert!(low > 0.25 && low < 0.45, "low-opacity fraction {low}");
        assert!(high > 0.5, "high-opacity fraction {high}");
    }

    #[test]
    fn has_heavy_scale_tail() {
        let s = synth_scene(SceneClass::SyntheticSmall, 11, 20_000);
        let mut geo: Vec<f32> = (0..s.len()).map(|i| s.geo_mean_scale(i)).collect();
        geo.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = geo[geo.len() / 2];
        let p999 = geo[(geo.len() as f32 * 0.999) as usize];
        assert!(p999 > 3.0 * median, "p99.9 {p999} vs median {median}");
    }

    #[test]
    fn extent_scales_with_class() {
        let small = synth_scene(SceneClass::SyntheticSmall, 2, 1000);
        let big = synth_scene(SceneClass::RealUnbounded, 2, 1000);
        let (lo_s, hi_s) = small.bounds();
        let (lo_b, hi_b) = big.bounds();
        assert!((hi_b - lo_b).norm() > 3.0 * (hi_s - lo_s).norm());
    }
}
