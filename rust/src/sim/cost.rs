//! Pluggable per-frame cost models over the stage graph's
//! [`FrameWorkload`] record.
//!
//! Two seams price one frame:
//!
//! * [`FrontendCostModel`] — projection + sorting (+ the per-frame S²
//!   refresh). Implemented by [`GpuModel`] (the mobile GPU runs the
//!   frontend) and [`GsCoreModel`] (CCU + GSU, the Sec. 6.4 comparison).
//! * [`CostModel`] — rasterization + fixed per-frame overhead.
//!   Implemented by [`GpuModel`] (SIMT warp model, RC lookup overhead),
//!   [`LuminCoreSim`] (cycle-accurate NRU array), and [`GsCoreModel`]
//!   (dense rasterizer without frontend/backend decoupling).
//!
//! The coordinator composes one of each as trait objects; every model
//! reads only the measured workload, so no implementor needs to know
//! which [`crate::config::HardwareVariant`] is being evaluated.

use crate::pipeline::stage::{AggregateWorkload, FrameWorkload, FrontendWork};
use crate::sim::energy::{EnergyBreakdown, EnergyModel};
use crate::sim::gpu::{GpuModel, WarpAggregates};
use crate::sim::gscore::GsCoreModel;
use crate::sim::lumincore::{tiles_from_stats, LuminCoreSim};

/// Priced rasterization stage.
#[derive(Debug, Clone, Copy, Default)]
pub struct RasterCost {
    pub time_s: f64,
    pub energy: EnergyBreakdown,
    /// Compute-unit utilization during rasterization (0-1).
    pub pe_utilization: f64,
}

/// Prices the frontend (projection + sorting + refresh) of a frame.
pub trait FrontendCostModel: Send {
    fn label(&self) -> &'static str;

    /// Returns (seconds, joules) for a frame's frontend scalars — the
    /// shared entry for the per-pixel record and the O(tiles)
    /// aggregate, which carry identical frontend information.
    fn frontend_work_cost(&self, fw: &FrontendWork) -> (f64, f64);

    /// Returns (seconds, joules) for the frame's frontend work.
    fn frontend_cost(&self, w: &FrameWorkload) -> (f64, f64) {
        self.frontend_work_cost(&w.frontend_work())
    }

    /// Time to receive a pool-shared speculative sort of `entries`
    /// frozen tile-list entries instead of computing it — the
    /// clustered-S² follower's broadcast + arbitration term. It
    /// replaces the sort, never the per-frame refresh: the admission
    /// planner adds it on top of the refresh floor
    /// (`StagePrices::follower_front_s`). Defaults to 0 for units that
    /// never receive a shared sort.
    fn shared_sort_broadcast_s(&self, _entries: usize) -> f64 {
        0.0
    }
}

/// Prices the rasterization stage (and fixed overhead) of a frame.
pub trait CostModel: Send {
    fn label(&self) -> &'static str;

    /// True when this model prices cached frames from the *uncached*
    /// per-pixel counts (the GPU warp advances at the pace of its
    /// slowest miss lane, paper Sec. 4). The raster stage records them
    /// in its single pass when asked.
    fn needs_uncached_stats(&self) -> bool {
        false
    }

    /// Price the frame's rasterization.
    fn raster_cost(&mut self, w: &FrameWorkload) -> RasterCost;

    /// Price rasterization from an O(tiles) aggregate — the admission
    /// controller's fast rung-pricing path. Aggregates are built from
    /// normalized (cache-stripped) records, so no implementation needs
    /// cache-outcome handling; within-tile uniformity is assumed, with
    /// recorded maxima bounding the divergence-sensitive terms.
    fn raster_cost_aggregate(&mut self, a: &AggregateWorkload) -> RasterCost;

    /// Fixed per-frame overhead in seconds (kernel launches for the
    /// GPU; DMA descriptor setup for the accelerators).
    fn overhead_s(&self) -> f64;

    /// Pool-shared cache lookup contention for a frame of `pixels`
    /// lookups — the *structural* cost of sharing (paid warm or cold,
    /// at any tier; cache hits cannot save it). `probe_len` is the
    /// scope's worst-case probe-chain length (1 for the geometry
    /// scopes, `pool.world_probe_len` under world scope): each extra
    /// chain slot is another contended access, so the charge scales
    /// linearly. Implementations add it to
    /// `raster_cost`/`raster_cost_aggregate` whenever the workload's
    /// `cache_shared` flag is set, and the admission planner excludes
    /// it from the pool-hit-rate discount. 0 for models that never
    /// price a shared cache (GSCore's variant has no RC).
    fn shared_lookup_cost_s(&self, _pixels: usize, _probe_len: u32) -> f64 {
        0.0
    }
}

/// Cross-session sharing multiplies the GPU's RC lookup serialization:
/// other viewers' lookups contend for the same locks the paper blames
/// for RC-on-GPU's slowdown. Charged as a fraction of the
/// single-session lookup overhead.
const GPU_SHARED_LOOKUP_FACTOR: f64 = 0.5;

/// S² re-evaluates SH colors (and light per-Gaussian geometry) every
/// frame on the frontend unit: ~35% of a projection pass over the
/// refreshed set (paper Sec. 3.1 accounting).
const S2_REFRESH_PROJECTION_FRACTION: f64 = 0.35;

/// A pool-clustered follower receives the cluster's frozen tile lists
/// (DMA of the sorted entries + arbitration against its co-followers)
/// instead of sorting them: charged as a fraction of the unit's own
/// sorting-time primitive over the shared list size — streaming sorted
/// data is much cheaper than producing it, but not free.
const SORT_BROADCAST_FRACTION: f64 = 0.15;

/// Exact-intersection tile binning tests every rect-candidate
/// (splat, tile) pair before admitting it to the sort (see
/// `pipeline/sort.rs`): a closest-point distance check, much lighter
/// than a sort entry's key build + merge traffic. Charged as a fraction
/// of the unit's sorting-time primitive over the *candidate* count, so
/// the exact test's cost — and the entry shrinkage it buys downstream —
/// both show up in the sims.
const BIN_TEST_SORT_FRACTION: f64 = 0.12;

/// Shared frontend pricing shape: `sorted`-gated projection + binning +
/// sorting plus the per-frame S² refresh, parameterized by the unit's
/// two time primitives so GPU and CCU/GSU cannot drift apart.
fn frontend_time_s(
    fw: &FrontendWork,
    proj_time_s: impl Fn(usize) -> f64,
    sort_time_s: impl Fn(usize) -> f64,
) -> f64 {
    // Projection frustum-culls the whole scene, not just survivors.
    let proj = if fw.sorted { proj_time_s(fw.scene_gaussians) } else { 0.0 };
    let bin =
        if fw.sorted { BIN_TEST_SORT_FRACTION * sort_time_s(fw.bin_candidates) } else { 0.0 };
    let sort = if fw.sorted { sort_time_s(fw.sort_entries) } else { 0.0 };
    let refresh = S2_REFRESH_PROJECTION_FRACTION * proj_time_s(fw.refreshed_gaussians);
    proj + bin + sort + refresh
}

impl FrontendCostModel for GpuModel {
    fn label(&self) -> &'static str {
        "gpu-frontend"
    }

    fn frontend_work_cost(&self, fw: &FrontendWork) -> (f64, f64) {
        let t =
            frontend_time_s(fw, |n| self.projection_time_s(n), |e| self.sorting_time_s(e));
        (t, EnergyModel::nm12().gpu_energy_j(t))
    }

    fn shared_sort_broadcast_s(&self, entries: usize) -> f64 {
        SORT_BROADCAST_FRACTION * self.sorting_time_s(entries)
    }
}

impl FrontendCostModel for GsCoreModel {
    fn label(&self) -> &'static str {
        "ccu-gsu"
    }

    fn frontend_work_cost(&self, fw: &FrontendWork) -> (f64, f64) {
        let t = frontend_time_s(fw, |n| self.ccu_time_s(n), |e| self.gsu_time_s(e));
        (t, self.energy_j(t))
    }

    fn shared_sort_broadcast_s(&self, entries: usize) -> f64 {
        SORT_BROADCAST_FRACTION * self.gsu_time_s(entries)
    }
}

impl CostModel for GpuModel {
    fn label(&self) -> &'static str {
        "gpu"
    }

    fn needs_uncached_stats(&self) -> bool {
        true
    }

    fn raster_cost(&mut self, w: &FrameWorkload) -> RasterCost {
        // RC-on-GPU pays warp-bound time: the warp advances at the pace
        // of its slowest (miss) lane, so cache hits do not shorten
        // rounds — price the *uncached* warp structure when recorded.
        // A cached workload without recorded uncached stats means the
        // raster backend was composed without honoring
        // `needs_uncached_stats`; the fallback below would then
        // underprice the frame (hits would shorten rounds).
        debug_assert!(
            !w.uses_cache() || w.uncached.is_some(),
            "cached workload priced by the GPU model without uncached stats"
        );
        let agg = match &w.uncached {
            Some(s) => WarpAggregates::from_stats(s, w.width, w.height),
            None => WarpAggregates::from_slices(&w.consumed, &w.significant, w.width, w.height),
        };
        let mut t = self.raster_time_s(&agg);
        if w.uses_cache() {
            // Lookup serialization + lock contention (paper Sec. 4).
            t += self.rc_overhead_time_s(w.pixels());
        }
        if w.cache_shared {
            // Cross-session lock contention on the shared cache — a
            // structural charge (independent of the stripped outcome
            // maps), so tier estimates keep paying it.
            t += CostModel::shared_lookup_cost_s(self, w.pixels(), w.shared_probe_len);
        }
        RasterCost {
            time_s: t,
            energy: EnergyBreakdown {
                gpu: EnergyModel::nm12().gpu_energy_j(t),
                ..Default::default()
            },
            pe_utilization: 1.0 - agg.masked_fraction(self),
        }
    }

    fn raster_cost_aggregate(&mut self, a: &AggregateWorkload) -> RasterCost {
        // Aggregates are cache-stripped (normalized), so no RC overhead:
        // same contract as pricing a normalized per-pixel estimate.
        let agg = WarpAggregates::from_tile_aggregates(&a.tiles);
        let mut t = self.raster_time_s(&agg);
        if a.cache_shared {
            // Same structural contention charge as the exact path.
            t += CostModel::shared_lookup_cost_s(self, a.width * a.height, a.shared_probe_len);
        }
        RasterCost {
            time_s: t,
            energy: EnergyBreakdown {
                gpu: EnergyModel::nm12().gpu_energy_j(t),
                ..Default::default()
            },
            pe_utilization: 1.0 - agg.masked_fraction(self),
        }
    }

    fn overhead_s(&self) -> f64 {
        self.launch_overhead_s
    }

    fn shared_lookup_cost_s(&self, pixels: usize, probe_len: u32) -> f64 {
        // Each probe-chain slot is another lock-serialized access, so
        // the chain bound multiplies the base contention (probe_len = 1
        // reproduces the geometry-scope charge exactly).
        f64::from(probe_len.max(1)) * GPU_SHARED_LOOKUP_FACTOR * self.rc_overhead_time_s(pixels)
    }
}

impl CostModel for LuminCoreSim {
    fn label(&self) -> &'static str {
        "lumincore"
    }

    fn raster_cost(&mut self, w: &FrameWorkload) -> RasterCost {
        let tiles = tiles_from_stats(
            &w.tile_list_lens,
            w.tiles_x,
            w.tiles_y,
            w.tile_size,
            w.width,
            w.height,
            &w.consumed,
            &w.significant,
            w.cache_outcomes.as_deref(),
        );
        let frame = self.frame(&tiles, w.swap_bytes);
        let mut energy = frame.energy;
        // The GPU idles (leakage only) while the NRUs rasterize.
        energy.gpu += self.energy.gpu_idle_energy_j(frame.raster_s);
        let mut time_s = frame.raster_s;
        if w.cache_shared {
            // Pool-shared LuminCache: every pixel's lookup pays bank
            // port arbitration against the other sessions. Bounded by
            // the pixel count (each pixel queries at most once); a
            // structural charge, so it survives the planner's
            // normalized tier estimates and admission pricing consumes
            // it.
            time_s += CostModel::shared_lookup_cost_s(self, w.pixels(), w.shared_probe_len);
        }
        RasterCost { time_s, energy, pe_utilization: frame.pe_utilization }
    }

    fn raster_cost_aggregate(&mut self, a: &AggregateWorkload) -> RasterCost {
        let frame = self.frame_from_aggregates(&a.tiles, a.swap_bytes);
        let mut energy = frame.energy;
        energy.gpu += self.energy.gpu_idle_energy_j(frame.raster_s);
        let mut time_s = frame.raster_s;
        if a.cache_shared {
            // Same structural contention charge as the exact path —
            // both derive it from the pixel count, so the two pricing
            // paths stay in lockstep.
            time_s += CostModel::shared_lookup_cost_s(self, a.width * a.height, a.shared_probe_len);
        }
        RasterCost { time_s, energy, pe_utilization: frame.pe_utilization }
    }

    fn overhead_s(&self) -> f64 {
        // Kernel launches are replaced by DMA descriptor setup; only a
        // sliver of the GPU's launch overhead remains.
        0.1 * GpuModel::xavier_volta().launch_overhead_s
    }

    fn shared_lookup_cost_s(&self, pixels: usize, probe_len: u32) -> f64 {
        // Every chain slot is another arbitration round against the
        // other sessions' ports (probe_len = 1 reproduces the
        // geometry-scope charge exactly).
        f64::from(probe_len.max(1)) * LuminCoreSim::shared_contention_s(self, pixels as u64)
    }
}

impl CostModel for GsCoreModel {
    fn label(&self) -> &'static str {
        "gscore"
    }

    fn raster_cost(&mut self, w: &FrameWorkload) -> RasterCost {
        let pairs: u64 = w.consumed.iter().map(|&v| v as u64).sum();
        let t = self.raster_time_s(pairs);
        RasterCost {
            time_s: t,
            energy: EnergyBreakdown { gpu: self.energy_j(t), ..Default::default() },
            pe_utilization: 1.0,
        }
    }

    fn raster_cost_aggregate(&mut self, a: &AggregateWorkload) -> RasterCost {
        // GSCore prices total Gaussian-pixel pairs: exact from the tile
        // sums — the aggregate path loses nothing here.
        let t = self.raster_time_s(a.iter_total());
        RasterCost {
            time_s: t,
            energy: EnergyBreakdown { gpu: self.energy_j(t), ..Default::default() },
            pe_utilization: 1.0,
        }
    }

    fn overhead_s(&self) -> f64 {
        GpuModel::xavier_volta().launch_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lumina::rc::CacheStats;

    fn workload(px: usize) -> FrameWorkload {
        let side = (px as f64).sqrt() as usize;
        FrameWorkload {
            frame: 0,
            width: side,
            height: side,
            tile_size: 16,
            tiles_x: side.div_ceil(16),
            tiles_y: side.div_ceil(16),
            tile_list_lens: vec![100; side.div_ceil(16) * side.div_ceil(16)],
            scene_gaussians: 10_000,
            sorted: true,
            sort_entries: 50_000,
            bin_candidates: 60_000,
            refreshed_gaussians: 0,
            consumed: vec![100; side * side],
            significant: vec![10; side * side],
            uncached: None,
            cache_outcomes: None,
            cache: CacheStats::default(),
            cache_shared: false,
            shared_probe_len: 1,
            swap_bytes: 0,
        }
    }

    #[test]
    fn gpu_model_prices_both_seams() {
        let gpu = GpuModel::xavier_volta();
        let w = workload(128 * 128);
        let (ft, fj) = gpu.frontend_cost(&w);
        assert!(ft > 0.0 && fj > 0.0);
        let mut gpu = gpu;
        let rc = gpu.raster_cost(&w);
        assert!(rc.time_s > 0.0 && rc.energy.total() > 0.0);
        assert!(rc.pe_utilization > 0.0 && rc.pe_utilization <= 1.0);
        assert!(gpu.overhead_s() > 0.0);
    }

    #[test]
    fn unsorted_frame_skips_frontend_work() {
        let gpu = GpuModel::xavier_volta();
        let mut w = workload(128 * 128);
        w.sorted = false;
        w.sort_entries = 0;
        w.bin_candidates = 0;
        let (t, _) = gpu.frontend_cost(&w);
        assert_eq!(t, 0.0, "no refresh and no sort => zero frontend time");
        w.refreshed_gaussians = 5000;
        let (t2, _) = gpu.frontend_cost(&w);
        assert!(t2 > 0.0, "S2 refresh still costs on shared frames");
    }

    #[test]
    fn cache_overhead_only_when_cached() {
        let mut gpu = GpuModel::xavier_volta();
        let mut w = workload(128 * 128);
        let plain = gpu.raster_cost(&w).time_s;
        w.cache_outcomes = Some(vec![1; w.pixels()]);
        w.uncached = Some(crate::pipeline::raster::RasterStats {
            iterated: w.consumed.clone(),
            significant: w.significant.clone(),
        });
        let cached = gpu.raster_cost(&w).time_s;
        assert!(cached > plain, "RC on GPU must be pure overhead");
    }

    #[test]
    fn lumincore_beats_gpu_on_same_workload() {
        let mut gpu = GpuModel::xavier_volta();
        let mut lc = LuminCoreSim::paper_default();
        let w = workload(256 * 256);
        let tg = gpu.raster_cost(&w).time_s;
        let tl = lc.raster_cost(&w).time_s;
        assert!(tl < tg, "LuminCore {tl} should beat GPU {tg}");
        assert!(lc.overhead_s() < gpu.overhead_s());
    }

    #[test]
    fn aggregate_pricing_matches_exact_on_uniform_workloads() {
        // The O(tiles) path's within-tile uniformity assumption is
        // exact on a uniform record: all three models must agree with
        // the per-pixel path (to float-summation-order noise).
        let w = workload(64 * 64);
        let a = w.aggregate();
        let mut gpu = GpuModel::xavier_volta();
        let exact = gpu.raster_cost(&w).time_s;
        let agg = gpu.raster_cost_aggregate(&a).time_s;
        assert!((exact - agg).abs() <= 1e-9 * exact, "gpu {exact} vs {agg}");
        let mut lc = LuminCoreSim::paper_default();
        let exact = lc.raster_cost(&w).time_s;
        let agg = lc.raster_cost_aggregate(&a).time_s;
        assert!((exact - agg).abs() <= 1e-9 * exact, "lumincore {exact} vs {agg}");
        let mut gs = GsCoreModel::published();
        assert_eq!(
            gs.raster_cost(&w).time_s,
            gs.raster_cost_aggregate(&a).time_s,
            "gscore aggregate pricing is exact by construction"
        );
        // Frontend scalars travel identically through both records.
        let gpu = GpuModel::xavier_volta();
        assert_eq!(gpu.frontend_cost(&w), gpu.frontend_work_cost(&a.frontend_work()));
    }

    #[test]
    fn lumincore_charges_shared_lookup_contention() {
        // A shared-scope workload must price strictly above its private
        // twin (the paper's lock-contention concern, as a cost), and
        // the exact and aggregate paths must charge it identically.
        let mut lc = LuminCoreSim::paper_default();
        let w = workload(64 * 64);
        let mut shared = w.clone();
        shared.cache_shared = true;
        let private_t = lc.raster_cost(&w).time_s;
        let shared_t = lc.raster_cost(&shared).time_s;
        let contention = lc.shared_contention_s((64 * 64) as u64);
        assert!(contention > 0.0);
        assert!(
            (shared_t - private_t - contention).abs() < 1e-15,
            "shared {shared_t} vs private {private_t} + contention {contention}"
        );
        let agg = shared.aggregate();
        assert!(agg.cache_shared, "aggregation must keep the scope flag");
        let agg_t = lc.raster_cost_aggregate(&agg).time_s;
        let agg_private_t = lc.raster_cost_aggregate(&w.aggregate()).time_s;
        assert!((agg_t - agg_private_t - contention).abs() < 1e-15);
    }

    #[test]
    fn gpu_charges_shared_lookup_contention_too() {
        // RC-on-GPU under shared scope pays extra lock serialization —
        // the discount-eligible variants and the contention-charging
        // variants must be the same set, or shared pricing would be
        // strictly optimistic on GPU pools.
        let mut gpu = GpuModel::xavier_volta();
        let w = workload(64 * 64);
        let mut shared = w.clone();
        shared.cache_shared = true;
        let expect = CostModel::shared_lookup_cost_s(&gpu, 64 * 64, 1);
        assert!(expect > 0.0);
        let d = gpu.raster_cost(&shared).time_s - gpu.raster_cost(&w).time_s;
        assert!((d - expect).abs() < 1e-15, "exact path: {d} vs {expect}");
        let agg_d = gpu.raster_cost_aggregate(&shared.aggregate()).time_s
            - gpu.raster_cost_aggregate(&w.aggregate()).time_s;
        assert!((agg_d - expect).abs() < 1e-15, "aggregate path: {agg_d} vs {expect}");
    }

    #[test]
    fn probe_chain_length_multiplies_shared_contention() {
        // World scope's bounded probing: each chain slot is another
        // contended access, so the charge is linear in the bound on
        // both RC-capable models — and probe_len = 1 reproduces the
        // geometry-scope charge exactly (backward compatibility of the
        // widened seam).
        let lc = LuminCoreSim::paper_default();
        let one = CostModel::shared_lookup_cost_s(&lc, 64 * 64, 1);
        assert_eq!(one, lc.shared_contention_s((64 * 64) as u64));
        let three = CostModel::shared_lookup_cost_s(&lc, 64 * 64, 3);
        assert!((three - 3.0 * one).abs() <= 1e-12 * one, "{three} vs 3x{one}");
        let gpu = GpuModel::xavier_volta();
        let one = CostModel::shared_lookup_cost_s(&gpu, 64 * 64, 1);
        assert_eq!(one, GPU_SHARED_LOOKUP_FACTOR * gpu.rc_overhead_time_s(64 * 64));
        let three = CostModel::shared_lookup_cost_s(&gpu, 64 * 64, 3);
        assert!((three - 3.0 * one).abs() <= 1e-12 * one, "{three} vs 3x{one}");
        // GSCore has no RC: chain length cannot conjure a charge.
        let gs = GsCoreModel::published();
        assert_eq!(CostModel::shared_lookup_cost_s(&gs, 64 * 64, 3), 0.0);
    }

    #[test]
    fn sort_broadcast_is_cheaper_than_sorting() {
        // Receiving a frozen sort must cost something (DMA +
        // arbitration) but strictly less than producing it — on both
        // frontend units — or clustering could never pay.
        let entries = 50_000;
        let gpu = GpuModel::xavier_volta();
        let b = gpu.shared_sort_broadcast_s(entries);
        assert!(b > 0.0);
        assert!(b < gpu.sorting_time_s(entries));
        let gs = GsCoreModel::published();
        let b = gs.shared_sort_broadcast_s(entries);
        assert!(b > 0.0);
        assert!(b < gs.gsu_time_s(entries));
    }

    #[test]
    fn binning_candidates_priced_but_cheaper_than_sorting_them() {
        // The exact-intersection test costs per candidate on sorted
        // frames — but strictly less than sorting the candidate set
        // would, or culling could never pay. Shape holds on both
        // frontend units via the shared pricing helper.
        let gpu = GpuModel::xavier_volta();
        let w = workload(128 * 128);
        let (base, _) = gpu.frontend_cost(&w);
        let mut more = w.clone();
        more.bin_candidates *= 2;
        let (t_more, _) = gpu.frontend_cost(&more);
        assert!(t_more > base, "more candidates must cost more");
        let d = t_more - base;
        assert!(d < gpu.sorting_time_s(w.bin_candidates), "test {d} cheaper than sorting");
        let gs = GsCoreModel::published();
        let (base, _) = gs.frontend_work_cost(&w.frontend_work());
        let (t_more, _) = gs.frontend_work_cost(&more.frontend_work());
        assert!(t_more > base);
        assert!(t_more - base < gs.gsu_time_s(w.bin_candidates));
    }

    #[test]
    fn gscore_prices_pairs() {
        let mut gs = GsCoreModel::published();
        let w = workload(128 * 128);
        let rc = gs.raster_cost(&w);
        assert!(rc.time_s > 0.0);
        let (ft, fj) = FrontendCostModel::frontend_cost(&gs, &w);
        assert!(ft > 0.0 && fj > 0.0);
    }
}
