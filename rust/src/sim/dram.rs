//! LPDDR3-1600 DRAM model (paper Sec. 5: Micron 16 Gb LPDDR3-1600,
//! four channels, energy from the Micron system power calculators).
//!
//! The simulator charges bandwidth-limited transfer time and per-byte
//! access energy; random-access energy sits ~25x above SRAM access
//! energy per byte (paper cites [30, 76]).

/// LPDDR3-1600 x4-channel timing/energy model.
#[derive(Debug, Clone, Copy)]
pub struct DramModel {
    /// Sustained bandwidth in bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// First-word latency in seconds (row activate + CAS).
    pub latency_s: f64,
    /// Energy per byte transferred (J/B).
    pub energy_per_byte: f64,
}

impl DramModel {
    /// Paper configuration: LPDDR3-1600, 32-bit channels, 4 channels.
    /// 1600 MT/s * 4 B/transfer * 4 ch = 25.6 GB/s peak; ~70% sustained.
    /// Energy ~ 40 pJ/B at LPDDR3 voltages (Micron calculator scale).
    pub fn lpddr3_1600_x4() -> Self {
        DramModel {
            bandwidth_bytes_per_s: 25.6e9 * 0.7,
            latency_s: 60e-9,
            energy_per_byte: 40e-12,
        }
    }

    /// Time to stream `bytes` (one burst; latency amortized per request).
    pub fn transfer_time_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Energy to move `bytes`.
    pub fn transfer_energy_j(&self, bytes: usize) -> f64 {
        bytes as f64 * self.energy_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_free() {
        let d = DramModel::lpddr3_1600_x4();
        assert_eq!(d.transfer_time_s(0), 0.0);
        assert_eq!(d.transfer_energy_j(0), 0.0);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let d = DramModel::lpddr3_1600_x4();
        let t = d.transfer_time_s(1 << 30); // 1 GiB
        let ideal = (1u64 << 30) as f64 / d.bandwidth_bytes_per_s;
        assert!((t - ideal) / ideal < 0.01);
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let d = DramModel::lpddr3_1600_x4();
        let t = d.transfer_time_s(64);
        assert!(t > 0.9 * d.latency_s && t < 2.0 * d.latency_s);
    }

    #[test]
    fn energy_linear() {
        let d = DramModel::lpddr3_1600_x4();
        assert!(
            (d.transfer_energy_j(2000) - 2.0 * d.transfer_energy_j(1000)).abs() < 1e-18
        );
    }
}
