//! Energy model: per-component constants at the 12 nm node (paper Sec. 5:
//! RTL synthesized at TSMC 16 nm, scaled to 12 nm with DeepScaleTool to
//! match the Xavier SoC; SRAM via the Arm Artisan compiler; DRAM:SRAM
//! random-access energy ratio ~25:1).
//!
//! All values are *component-level* constants, exactly the granularity the
//! paper's own simulator uses — we start from the same published numbers
//! rather than re-running synthesis (DESIGN.md §8).

/// Energy constants for the accelerator datapath + memories.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// One PE frontend op: 3 muls + 3 MACs + exp-decay compare (J/op).
    pub pe_frontend_op: f64,
    /// One backend color-integration op: exp + 3 MACs (J/op).
    pub backend_op: f64,
    /// LuminCache lookup: 4-way tag compare + data read (J/lookup).
    pub cache_lookup: f64,
    /// SRAM access energy per byte (feature/output buffers).
    pub sram_per_byte: f64,
    /// DRAM access energy per byte (25x SRAM per the paper).
    pub dram_per_byte: f64,
    /// Mobile GPU average power under rendering load (W). The Xavier
    /// module is ~30 W board power; the GPU rail under 3DGS load sits
    /// near 15 W (paper measures with the built-in rails).
    pub gpu_power_w: f64,
    /// GPU idle/leakage floor while the accelerator renders (W).
    pub gpu_idle_w: f64,
}

impl EnergyModel {
    /// 12 nm-scaled defaults.
    pub fn nm12() -> Self {
        let sram_per_byte = 1.6e-12; // ~1.6 pJ/B at 12 nm
        EnergyModel {
            // ~6 arithmetic ops at ~0.5 pJ each (12 nm, f32 datapath).
            pe_frontend_op: 3.0e-12,
            // exp unit + blend MACs.
            backend_op: 4.0e-12,
            // 4 tag compares (10 B each) + 3 B data read + control.
            cache_lookup: 8.0e-12,
            sram_per_byte,
            dram_per_byte: 25.0 * sram_per_byte, // paper's 25:1 ratio
            gpu_power_w: 15.0,
            gpu_idle_w: 1.5,
        }
    }

    /// GPU energy for a stage of duration `t` seconds.
    pub fn gpu_energy_j(&self, t_s: f64) -> f64 {
        self.gpu_power_w * t_s
    }

    /// GPU leakage while idle for `t` seconds.
    pub fn gpu_idle_energy_j(&self, t_s: f64) -> f64 {
        self.gpu_idle_w * t_s
    }
}

/// Per-frame energy breakdown in joules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub gpu: f64,
    pub nru_compute: f64,
    pub cache: f64,
    pub sram: f64,
    pub dram: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.gpu + self.nru_compute + self.cache + self.sram + self.dram
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.gpu += o.gpu;
        self.nru_compute += o.nru_compute;
        self.cache += o.cache;
        self.sram += o.sram;
        self.dram += o.dram;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_sram_ratio_is_25() {
        let e = EnergyModel::nm12();
        assert!((e.dram_per_byte / e.sram_per_byte - 25.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_energy_linear_in_time() {
        let e = EnergyModel::nm12();
        assert!((e.gpu_energy_j(2.0) - 2.0 * e.gpu_energy_j(1.0)).abs() < 1e-12);
    }

    #[test]
    fn breakdown_totals() {
        let mut b = EnergyBreakdown { gpu: 1.0, nru_compute: 0.5, cache: 0.1, sram: 0.2, dram: 0.3 };
        assert!((b.total() - 2.1).abs() < 1e-12);
        b.add(&b.clone());
        assert!((b.total() - 4.2).abs() < 1e-12);
    }
}
