//! Mobile Volta GPU cost model (the paper's measurement baseline,
//! substituted per DESIGN.md §8 by an analytical + trace-driven SIMT
//! model calibrated to the paper's published anchors: 5-66 FPS across
//! scene classes, a ~10/23/67 projection/sorting/rasterization split,
//! and ~69% masked threads during rasterization).
//!
//! Rasterization is modeled at warp granularity from the *real* per-pixel
//! iterated/significant counts of the functional rasterizer: a warp of 32
//! pixels executes rounds over its tile's Gaussian list; every round pays
//! a frontend (fetch + alpha) issue, and any round with at least one
//! significant lane pays a blend issue with the other lanes masked —
//! exactly the divergence of paper Fig. 5.

use crate::pipeline::raster::RasterStats;
use crate::pipeline::stage::TileAggregate;

/// Xavier-like mobile Volta parameters.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// SM clock in Hz (Xavier Volta: ~1.377 GHz).
    pub clock_hz: f64,
    /// Warp instructions issued per cycle across the whole GPU
    /// (8 SMs x 2 issue ~ 16; derated for memory stalls).
    pub warp_issue_per_cycle: f64,
    /// Cycles per Gaussian for the frontend work of one warp round
    /// (global->shared fetch amortized + alpha evaluation).
    pub front_cycles: f64,
    /// Cycles for one blend round of a warp (color integration issue).
    pub blend_cycles: f64,
    /// Cycles per Gaussian for Projection (EWA + SH color, vectorized).
    pub proj_cycles_per_gaussian: f64,
    /// Cycles per tile-list entry for Sorting (GPU radix over
    /// (tile, depth) keys; several passes over the key array).
    pub sort_cycles_per_entry: f64,
    /// Fixed kernel-launch overhead per frame (s). The paper includes
    /// measured launch times; a 3DGS frame issues tens of kernels.
    pub launch_overhead_s: f64,
    /// Extra per-pixel cycles when the RC cache runs on the GPU:
    /// lookup serialization + lock contention (paper Sec. 4: RC-GPU is
    /// a net slowdown).
    pub rc_gpu_overhead_cycles_per_pixel: f64,
}

impl GpuModel {
    /// Calibrated to the paper's published anchors (DESIGN.md §8):
    /// at paper-scale workloads (~1000 Gaussians iterated/pixel, ~10%
    /// significant, 800x800, ~3M sort entries) this lands at ~10 FPS
    /// with a 10/23/67 projection/sorting/rasterization split and ~69%
    /// masked lanes. `blend_cycles` > `front_cycles` reflects the SFU-
    /// bound exp() + read-modify-write of the integration round, vs the
    /// shared-memory-amortized fetch/alpha of the frontend.
    pub fn xavier_volta() -> Self {
        GpuModel {
            clock_hz: 1.377e9,
            warp_issue_per_cycle: 15.0,
            front_cycles: 16.0,
            blend_cycles: 50.0,
            proj_cycles_per_gaussian: 85.0,
            sort_cycles_per_entry: 86.0,
            launch_overhead_s: 0.5e-3,
            rc_gpu_overhead_cycles_per_pixel: 1800.0,
        }
    }
}

/// Warp-level aggregates extracted from per-pixel rasterizer statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct WarpAggregates {
    /// Sum over warps of the longest per-lane iteration count — the
    /// number of frontend rounds each warp must execute.
    pub warp_rounds: f64,
    /// Sum over warps of expected blend rounds (rounds with >=1
    /// significant lane).
    pub blend_rounds: f64,
    /// Total lane-rounds actually doing frontend work (unmasked).
    pub active_front_lane_rounds: f64,
    /// Total lane-rounds actually blending (significant lanes).
    pub active_blend_lane_rounds: f64,
    /// Number of warps.
    pub warps: u64,
}

impl WarpAggregates {
    /// Build warp aggregates from per-pixel stats. Warps are 32-lane
    /// groups covering two 16-pixel rows of a tile (the CUDA 3DGS
    /// mapping: one thread per pixel).
    pub fn from_stats(stats: &RasterStats, width: usize, height: usize) -> Self {
        Self::from_slices(&stats.iterated, &stats.significant, width, height)
    }

    /// Build warp aggregates from raw per-pixel slices (row-major).
    pub fn from_slices(
        iterated: &[u32],
        significant: &[u32],
        width: usize,
        height: usize,
    ) -> Self {
        let mut agg = WarpAggregates::default();
        let tile = 16usize;
        let mut lanes_iter = [0u32; 32];
        let mut lanes_sig = [0u32; 32];
        for ty in (0..height).step_by(2) {
            for tx in (0..width).step_by(tile) {
                // One warp: rows ty, ty+1, columns tx..tx+16.
                let mut n = 0usize;
                for dy in 0..2usize {
                    let y = ty + dy;
                    if y >= height {
                        continue;
                    }
                    for dx in 0..tile {
                        let x = tx + dx;
                        if x >= width {
                            continue;
                        }
                        lanes_iter[n] = iterated[y * width + x];
                        lanes_sig[n] = significant[y * width + x];
                        n += 1;
                    }
                }
                if n == 0 {
                    continue;
                }
                let max_iter = *lanes_iter[..n].iter().max().unwrap() as f64;
                let sum_iter: u64 = lanes_iter[..n].iter().map(|&v| v as u64).sum();
                let sum_sig: u64 = lanes_sig[..n].iter().map(|&v| v as u64).sum();
                // Expected blend rounds: rounds where >=1 lane blends.
                // With per-round significance probability p (average over
                // live lanes), P(any) = 1 - (1-p)^lanes.
                let p = if sum_iter > 0 {
                    sum_sig as f64 / sum_iter as f64
                } else {
                    0.0
                };
                let blend = if max_iter > 0.0 {
                    max_iter * (1.0 - (1.0 - p).powi(n as i32))
                } else {
                    0.0
                };
                agg.warp_rounds += max_iter;
                agg.blend_rounds += blend;
                agg.active_front_lane_rounds += sum_iter as f64;
                agg.active_blend_lane_rounds += sum_sig as f64;
                agg.warps += 1;
            }
        }
        agg
    }

    /// Warp aggregates from O(tiles) per-tile statistics — the
    /// admission controller's fast pricing path. Every warp in a tile
    /// is assumed to run to the tile's deepest lane (`iter_max`) with
    /// the tile's mean significance probability: an upper bound on the
    /// exact per-warp maxima (equal when the tile is uniform), keeping
    /// the estimates on the refuse-rather-than-miss side.
    pub fn from_tile_aggregates(tiles: &[TileAggregate]) -> Self {
        let mut agg = WarpAggregates::default();
        for t in tiles {
            if t.pixels() == 0 {
                continue;
            }
            // Warps are 2-row x 16-col image groups; with 16-px tiles
            // the warp grid aligns with the tile grid, so a partial
            // edge tile still spans ceil(h/2) x ceil(w/16) warps (of
            // fewer live lanes) — counting ceil(pixels/32) instead
            // would underprice edge columns and rows.
            let warps = u64::from(t.height.div_ceil(2)) * u64::from(t.width.div_ceil(16));
            // Live lanes per warp: two rows of the tile's width,
            // capped at the warp size (over-estimates the last odd
            // row's warp — the conservative side).
            let lanes = (2 * t.width).min(32).max(1) as i32;
            let max = f64::from(t.iter_max);
            let p = if t.iter_sum > 0 {
                t.sig_sum as f64 / t.iter_sum as f64
            } else {
                0.0
            };
            let blend = if max > 0.0 { max * (1.0 - (1.0 - p).powi(lanes)) } else { 0.0 };
            agg.warp_rounds += warps as f64 * max;
            agg.blend_rounds += warps as f64 * blend;
            agg.active_front_lane_rounds += t.iter_sum as f64;
            agg.active_blend_lane_rounds += t.sig_sum as f64;
            agg.warps += warps;
        }
        agg
    }

    /// Fraction of lane-rounds masked (paper Fig. 5: ~69%).
    pub fn masked_fraction(&self, model: &GpuModel) -> f64 {
        let issued_lane_cycles = 32.0
            * (self.warp_rounds * model.front_cycles + self.blend_rounds * model.blend_cycles);
        let useful = self.active_front_lane_rounds * model.front_cycles
            + self.active_blend_lane_rounds * model.blend_cycles;
        if issued_lane_cycles <= 0.0 {
            0.0
        } else {
            1.0 - useful / issued_lane_cycles
        }
    }
}

/// Per-frame GPU stage times in seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GpuStageTimes {
    pub projection: f64,
    pub sorting: f64,
    pub rasterization: f64,
    pub overhead: f64,
}

impl GpuStageTimes {
    pub fn total(&self) -> f64 {
        self.projection + self.sorting + self.rasterization + self.overhead
    }
}

impl GpuModel {
    /// Projection stage time for `n` scene Gaussians.
    pub fn projection_time_s(&self, n: usize) -> f64 {
        // Projection is lane-parallel and regular: utilization ~ full.
        n as f64 * self.proj_cycles_per_gaussian / (self.warp_issue_per_cycle * 32.0)
            / self.clock_hz
            * 32.0
    }

    /// Sorting stage time for `entries` tile-list entries.
    pub fn sorting_time_s(&self, entries: usize) -> f64 {
        entries as f64 * self.sort_cycles_per_entry / self.warp_issue_per_cycle
            / self.clock_hz
    }

    /// Rasterization stage time from warp aggregates.
    pub fn raster_time_s(&self, agg: &WarpAggregates) -> f64 {
        let warp_cycles =
            agg.warp_rounds * self.front_cycles + agg.blend_rounds * self.blend_cycles;
        warp_cycles / self.warp_issue_per_cycle / self.clock_hz
    }

    /// Extra time when radiance caching runs on the GPU (RC-GPU variant):
    /// per-pixel lookup serialization + lock contention. `pixels` is the
    /// framebuffer size.
    pub fn rc_overhead_time_s(&self, pixels: usize) -> f64 {
        pixels as f64 * self.rc_gpu_overhead_cycles_per_pixel
            / (self.warp_issue_per_cycle * 32.0)
            / self.clock_hz
    }

    /// Full-frame GPU times for the classic 3DGS pipeline.
    pub fn frame_times(
        &self,
        scene_gaussians: usize,
        sort_entries: usize,
        agg: &WarpAggregates,
    ) -> GpuStageTimes {
        GpuStageTimes {
            projection: self.projection_time_s(scene_gaussians),
            sorting: self.sorting_time_s(sort_entries),
            rasterization: self.raster_time_s(agg),
            overhead: self.launch_overhead_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Intrinsics, Pose};
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::pipeline::raster::{rasterize, RasterConfig};
    use crate::pipeline::sort::bin_and_sort;
    use crate::scene::synth::test_scene;

    fn real_stats() -> (RasterStats, usize, usize) {
        let scene = test_scene(5, 8000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let out = rasterize(&p, &bins, intr.width, intr.height, &cfg);
        (out.stats.unwrap(), intr.width, intr.height)
    }

    #[test]
    fn aggregates_consistent() {
        let (stats, w, h) = real_stats();
        let agg = WarpAggregates::from_stats(&stats, w, h);
        assert!(agg.warps > 0);
        // max >= mean: warp rounds >= active/32.
        assert!(agg.warp_rounds * 32.0 >= agg.active_front_lane_rounds);
        assert!(agg.blend_rounds <= agg.warp_rounds + 1e-9);
        assert!(agg.active_blend_lane_rounds <= agg.active_front_lane_rounds);
    }

    #[test]
    fn masked_fraction_realistic() {
        // Paper Fig. 5: threads masked ~69% (+-10%) of the time.
        let (stats, w, h) = real_stats();
        let agg = WarpAggregates::from_stats(&stats, w, h);
        let m = agg.masked_fraction(&GpuModel::xavier_volta());
        // The small unit-test scene is denser (higher significant
        // fraction) than paper-scale scenes, so its divergence is milder;
        // the paper-scale ~69% anchor is checked in
        // `raster_dominates_at_paper_scale`.
        assert!(m > 0.2 && m < 0.95, "masked fraction {m}");
    }

    #[test]
    fn stage_times_positive_and_ordered() {
        let (stats, w, h) = real_stats();
        let agg = WarpAggregates::from_stats(&stats, w, h);
        let gpu = GpuModel::xavier_volta();
        let t = gpu.frame_times(8000, 50_000, &agg);
        assert!(t.projection > 0.0 && t.sorting > 0.0 && t.rasterization > 0.0);
        assert!(t.total() > t.rasterization);
    }

    #[test]
    fn raster_dominates_at_paper_scale() {
        // With paper-scale workloads (hundreds of Gaussians iterated per
        // pixel), rasterization must dominate sorting and projection
        // (paper Fig. 3: 67% vs 23% vs ~10%).
        let gpu = GpuModel::xavier_volta();
        // Synthetic paper-scale numbers: 500k projected, 3M sort entries,
        // 800x800 px, 1000 iterated/px, 10% significant.
        let px = 800 * 800;
        let warps = (px / 32) as u64;
        let agg = WarpAggregates {
            warp_rounds: warps as f64 * 1100.0, // max ~ 1.1x mean
            blend_rounds: warps as f64 * 1050.0, // p=0.1 -> almost every round
            active_front_lane_rounds: px as f64 * 1000.0,
            active_blend_lane_rounds: px as f64 * 100.0,
            warps,
        };
        let t = gpu.frame_times(500_000, 3_000_000, &agg);
        let raster_share = t.rasterization / t.total();
        assert!(
            raster_share > 0.55 && raster_share < 0.88,
            "raster share {raster_share} (paper: 67%)"
        );
        let sort_share = t.sorting / t.total();
        assert!(sort_share > 0.08 && sort_share < 0.35, "sort share {sort_share} (paper: 23%)");
        // Masked fraction at paper statistics ~69% +- 10% (Fig. 5).
        let m = agg.masked_fraction(&gpu);
        assert!(m > 0.59 && m < 0.79, "masked {m} (paper: 0.69)");
        // Frame rate lands in the paper's real-scene range (5-21 FPS).
        let fps = 1.0 / t.total();
        assert!(fps > 4.0 && fps < 25.0, "fps {fps}");
    }

    #[test]
    fn rc_on_gpu_is_pure_overhead() {
        let gpu = GpuModel::xavier_volta();
        assert!(gpu.rc_overhead_time_s(800 * 800) > 0.0);
    }
}
