//! GSCore comparator model (paper Sec. 6.4).
//!
//! GSCore [47] is the prior state-of-the-art 3DGS accelerator: a Culling
//! & Conversion Unit (CCU) for Projection, a Gaussian Sorting Unit (GSU)
//! for Sorting, and a rasterizer *without* LuminCore's frontend/backend
//! decoupling — its blending lanes stall on insignificant Gaussians the
//! same way GPU warps do, which is why the paper's baseline-hardware
//! comparison (Fig. 25) favors LuminCore 9.6x vs GSCore 3.2x over the
//! GPU. We model GSCore from its published anchors (DESIGN.md §8):
//! dedicated-unit throughputs for CCU/GSU and a rasterizer whose
//! end-to-end effect lands at ~3.2x the GPU baseline on paper-scale
//! workloads.
//!
//! The same CCU/GSU front half also hosts the Sec. 6.4 "fair comparison"
//! variants: Lumina's NRU rasterizer fed by GSCore's projection/sorting
//! units instead of the mobile GPU.

/// GSCore unit throughput model.
#[derive(Debug, Clone, Copy)]
pub struct GsCoreModel {
    /// Clock of the accelerator units (Hz).
    pub clock_hz: f64,
    /// CCU throughput: Gaussians projected per cycle.
    pub ccu_gaussians_per_cycle: f64,
    /// GSU throughput: tile-list entries sorted per cycle (bitonic-merge
    /// hardware sorter).
    pub gsu_entries_per_cycle: f64,
    /// Rasterizer: Gaussian-pixel pairs evaluated per cycle across the
    /// array (dense, no frontend/backend split).
    pub raster_pairs_per_cycle: f64,
    /// Blend occupancy penalty: fraction of raster issue slots lost to
    /// insignificant Gaussians stalling the blend lanes.
    pub raster_stall_factor: f64,
    /// Average accelerator power (W), for energy comparisons.
    pub power_w: f64,
}

impl GsCoreModel {
    /// Anchored to GSCore's published ~3.2x end-to-end speedup over a
    /// mobile GPU baseline at paper-scale workloads.
    pub fn published() -> Self {
        GsCoreModel {
            clock_hz: 1.0e9,
            ccu_gaussians_per_cycle: 16.0,
            gsu_entries_per_cycle: 16.0,
            raster_pairs_per_cycle: 40.0,
            raster_stall_factor: 0.45,
            power_w: 1.2,
        }
    }

    /// Projection time on the CCU.
    pub fn ccu_time_s(&self, gaussians: usize) -> f64 {
        gaussians as f64 / self.ccu_gaussians_per_cycle / self.clock_hz
    }

    /// Sorting time on the GSU.
    pub fn gsu_time_s(&self, entries: usize) -> f64 {
        entries as f64 / self.gsu_entries_per_cycle / self.clock_hz
    }

    /// Rasterization time: total per-pixel Gaussian evaluations divided
    /// by effective throughput (stall-derated).
    pub fn raster_time_s(&self, gaussian_pixel_pairs: u64) -> f64 {
        gaussian_pixel_pairs as f64
            / (self.raster_pairs_per_cycle * (1.0 - self.raster_stall_factor))
            / self.clock_hz
    }

    /// Energy for a stage of duration `t`.
    pub fn energy_j(&self, t_s: f64) -> f64 {
        self.power_w * t_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{GpuModel, WarpAggregates};

    /// Paper-scale workload constants shared with the other sim tests.
    fn paper_workload() -> (usize, usize, u64, WarpAggregates) {
        let scene_gaussians = 500_000;
        let sort_entries = 3_000_000;
        let px = 800 * 800;
        let pairs = px as u64 * 1000; // ~1000 iterated per pixel
        let warps = (px / 32) as u64;
        let agg = WarpAggregates {
            warp_rounds: warps as f64 * 1100.0,
            blend_rounds: warps as f64 * 1050.0,
            active_front_lane_rounds: px as f64 * 1000.0,
            active_blend_lane_rounds: px as f64 * 100.0,
            warps,
        };
        (scene_gaussians, sort_entries, pairs, agg)
    }

    #[test]
    fn units_scale_linearly() {
        let g = GsCoreModel::published();
        assert!((g.ccu_time_s(2000) - 2.0 * g.ccu_time_s(1000)).abs() < 1e-12);
        assert!((g.gsu_time_s(2000) - 2.0 * g.gsu_time_s(1000)).abs() < 1e-12);
    }

    #[test]
    fn end_to_end_near_published_3_2x() {
        let g = GsCoreModel::published();
        let gpu = GpuModel::xavier_volta();
        let (n, entries, pairs, agg) = paper_workload();
        let gpu_total = gpu.frame_times(n, entries, &agg).total();
        let gs_total = g.ccu_time_s(n) + g.gsu_time_s(entries) + g.raster_time_s(pairs);
        let speedup = gpu_total / gs_total;
        assert!(
            speedup > 2.2 && speedup < 4.5,
            "GSCore end-to-end speedup {speedup} (published ~3.2x)"
        );
    }

    #[test]
    fn lumincore_raster_beats_gscore_raster() {
        // Fig. 25's root cause: frontend/backend decoupling. On the same
        // workload LuminCore's rasterizer must outrun GSCore's.
        use crate::sim::lumincore::{LuminCoreSim, TileWork};
        let g = GsCoreModel::published();
        let (_, _, pairs, _) = paper_workload();
        let gs_raster = g.raster_time_s(pairs);
        let sim = LuminCoreSim::paper_default();
        let n_tiles = (800 / 16) * (800 / 16);
        let tiles: Vec<TileWork> = (0..n_tiles)
            .map(|_| TileWork {
                list_len: 1000,
                consumed: vec![1000; 256],
                significant: vec![100; 256],
                cache: vec![0; 256],
            })
            .collect();
        let lc_raster = sim.frame(&tiles, 0).raster_s;
        assert!(
            lc_raster < gs_raster,
            "LuminCore {lc_raster}s should beat GSCore {gs_raster}s"
        );
    }
}
