//! Cycle-accurate LuminCore simulator (paper Sec. 4-5).
//!
//! Geometry (Sec. 5): 8x8 NRUs @ 1 GHz, four 3-stage-pipelined frontend
//! PEs per NRU, a backend (exp + color integration) shared by the four
//! PEs, double-buffered 176 KB Feature / 6 KB Output buffers, and the
//! shared LuminCache (timed here; functional behavior in `lumina::rc`).
//!
//! Execution model per 16x16 tile (one tile maps across the whole array:
//! 64 NRUs x 4 px = 256 px):
//!
//! * **Frontend**: each PE streams the tile's Gaussian list for its pixel,
//!   one Gaussian/cycle (+2 pipeline fill), pushing significant ones into
//!   the NRU FIFO. A pixel that terminated (or hit in the cache) stops
//!   consuming — in *normal* mode the NRU still runs until its slowest
//!   live pixel finishes.
//! * **Backend**: one significant Gaussian integrated per cycle, shared
//!   across the 4 PEs; the NRU's tile time is max(frontend, backend).
//! * **Sparsity-aware remapping** (Sec. 4): with RC enabled, cache-hit
//!   pixels idle their PEs; remapping lets an NRU's PEs cooperate on one
//!   pixel so frontend time becomes ceil(total work / 4) instead of
//!   max(per-pixel work).
//! * **Memory**: per tile, Gaussian features stream HBM->Feature Buffer
//!   (GAUSSIAN_FEATURE_BYTES each) in chunks bounded by the buffer size;
//!   double-buffering overlaps the next tile's load with this tile's
//!   compute, so frame time = sum over tiles of max(compute, dram).
//!   LuminCache group swaps charge additional DRAM traffic.

use crate::constants::{
    FEATURE_BUF_BYTES, GAUSSIAN_FEATURE_BYTES, NRU_ARRAY, NRU_CLOCK_HZ, OUTPUT_BUF_BYTES,
    PES_PER_NRU,
};
use crate::pipeline::stage::TileAggregate;
use crate::sim::dram::DramModel;
use crate::sim::energy::{EnergyBreakdown, EnergyModel};

/// Pipeline-fill cycles of the 3-stage PE.
const PE_FILL_CYCLES: u64 = 2;
/// Cycles for one LuminCache lookup (index + 4-way compare + select).
const CACHE_LOOKUP_CYCLES: u64 = 2;
/// Extra arbitration cycles a lookup pays when the LuminCache is
/// pool-shared: concurrent sessions probing one snapshot contend for
/// the bank read ports (the lock-contention hazard the paper ascribes
/// to RC-on-GPU, priced here instead of ignored so the cost model can
/// say when sharing stops paying).
pub const SHARED_LOOKUP_CONTENTION_CYCLES: u64 = 1;

/// LuminCore configuration (defaults = paper Sec. 5).
#[derive(Debug, Clone, Copy)]
pub struct LuminCoreConfig {
    pub nrus: usize,
    pub pes_per_nru: usize,
    pub clock_hz: f64,
    /// Sparsity-aware remapping of PEs to pixels (Sec. 4).
    pub sparsity_remap: bool,
}

impl Default for LuminCoreConfig {
    fn default() -> Self {
        LuminCoreConfig {
            nrus: NRU_ARRAY * NRU_ARRAY,
            pes_per_nru: PES_PER_NRU,
            clock_hz: NRU_CLOCK_HZ,
            sparsity_remap: true,
        }
    }
}

/// Per-tile workload handed to the simulator: what the functional
/// rasterizer actually did for each pixel of the tile.
#[derive(Debug, Clone, Default)]
pub struct TileWork {
    /// Gaussians in this tile's (shared) sorted list.
    pub list_len: u32,
    /// Per-pixel Gaussians consumed (early termination / RC cutoffs
    /// included). Length = tile pixel count.
    pub consumed: Vec<u32>,
    /// Per-pixel significant Gaussians encountered while consuming.
    pub significant: Vec<u32>,
    /// Per-pixel cache interaction: 0 = no RC, 1 = miss, 2 = hit.
    pub cache: Vec<u8>,
}

/// Per-frame simulation result.
#[derive(Debug, Clone, Copy, Default)]
pub struct LuminCoreFrame {
    /// Rasterization compute time (s).
    pub compute_s: f64,
    /// DRAM streaming time not hidden by double buffering (s).
    pub exposed_dram_s: f64,
    /// Total rasterization wall time (s).
    pub raster_s: f64,
    /// Total cycles across the frame (max over NRUs per tile, summed).
    pub cycles: u64,
    /// Feature-stream traffic (bytes).
    pub feature_bytes: u64,
    /// Cache swap traffic (bytes).
    pub cache_swap_bytes: u64,
    /// Mean PE utilization during frontend execution (0-1).
    pub pe_utilization: f64,
    /// Energy breakdown for the rasterization stage.
    pub energy: EnergyBreakdown,
}

/// The simulator itself.
#[derive(Debug, Clone)]
pub struct LuminCoreSim {
    pub cfg: LuminCoreConfig,
    pub dram: DramModel,
    pub energy: EnergyModel,
}

impl LuminCoreSim {
    pub fn paper_default() -> Self {
        LuminCoreSim {
            cfg: LuminCoreConfig::default(),
            dram: DramModel::lpddr3_1600_x4(),
            energy: EnergyModel::nm12(),
        }
    }

    /// Modeled port/lock-contention time for `lookups` shared-scope
    /// cache probes ([`SHARED_LOOKUP_CONTENTION_CYCLES`] each). Zero
    /// only when there are no lookups — a shared cache always pays
    /// arbitration, warm or cold.
    pub fn shared_contention_s(&self, lookups: u64) -> f64 {
        (lookups * SHARED_LOOKUP_CONTENTION_CYCLES) as f64 / self.cfg.clock_hz
    }

    /// Simulate one tile; returns (cycles, useful_pe_cycles, issued_pe_cycles).
    ///
    /// Pixels are assigned round-robin to (NRU, PE) slots; the tile's
    /// time is the max over NRUs of per-NRU time (all NRUs must finish
    /// before the output buffer flips).
    pub fn tile_cycles(&self, work: &TileWork) -> (u64, u64, u64) {
        let px = work.consumed.len();
        if px == 0 {
            return (0, 0, 0);
        }
        let mut useful = 0u64;
        let mut issued = 0u64;
        // Pixels assigned to NRUs in contiguous groups of pes_per_nru.
        let per_nru = self.cfg.pes_per_nru;
        // When the tile has more pixels than slots (not the default
        // geometry), groups wrap; accumulate per-NRU serial time.
        let mut nru_time = vec![0u64; self.cfg.nrus];
        for g in 0..px.div_ceil(per_nru) {
            let nru = g % self.cfg.nrus;
            let lo = g * per_nru;
            let hi = (lo + per_nru).min(px);
            let lane_work: Vec<u64> =
                (lo..hi).map(|i| work.consumed[i] as u64).collect();
            let sig_work: u64 =
                (lo..hi).map(|i| work.significant[i] as u64).sum();
            let lookups: u64 = (lo..hi)
                .filter(|&i| work.cache[i] != 0)
                .count() as u64;
            let front = if self.cfg.sparsity_remap {
                // PEs cooperate: total frontend work spread over PEs.
                let total: u64 = lane_work.iter().sum();
                total.div_ceil(per_nru as u64)
            } else {
                *lane_work.iter().max().unwrap_or(&0)
            };
            let backend = sig_work; // 1 significant Gaussian / cycle
            let t = front.max(backend) + PE_FILL_CYCLES + lookups * CACHE_LOOKUP_CYCLES;
            nru_time[nru] += t;
            useful += lane_work.iter().sum::<u64>() + sig_work;
            issued += front * per_nru as u64 + backend;
        }
        let max_nru = *nru_time.iter().max().unwrap_or(&0);
        (max_nru, useful, issued)
    }

    /// Simulate a frame from per-tile workloads.
    ///
    /// `extra_swap_bytes` charges LuminCache save/reload traffic
    /// (from `GroupedRadianceCache::swap_traffic_bytes`).
    pub fn frame(&self, tiles: &[TileWork], extra_swap_bytes: u64) -> LuminCoreFrame {
        let mut out = LuminCoreFrame::default();
        let mut useful = 0u64;
        let mut issued = 0u64;
        let mut lookups = 0u64;
        let mut sig_total = 0u64;
        let mut front_total = 0u64;
        for tile in tiles {
            let (cycles, u, i) = self.tile_cycles(tile);
            let compute_s = cycles as f64 / self.cfg.clock_hz;
            // Feature streaming for this tile (double-buffered): the DMA
            // walks the depth-sorted list in order and STOPS as soon as
            // every pixel of the tile has terminated (early termination
            // or a cache hit) — so the stream length is the deepest
            // consumed position, not the whole list. This is what makes
            // RC cut memory traffic alongside compute, and why the paper
            // can state that compute, not memory, dominates.
            let stream_len = tile.consumed.iter().copied().max().unwrap_or(0) as u64;
            let bytes = stream_len.min(tile.list_len as u64) * GAUSSIAN_FEATURE_BYTES as u64;
            let chunk = (FEATURE_BUF_BYTES / 2).max(1);
            let n_chunks = (bytes as usize).div_ceil(chunk);
            let dram_s = self.dram.transfer_time_s(bytes as usize)
                + (n_chunks.saturating_sub(1)) as f64 * 1e-9; // per-chunk handoff
            out.cycles += cycles;
            out.compute_s += compute_s;
            out.feature_bytes += bytes;
            // Double buffering: exposed memory time only beyond compute.
            out.exposed_dram_s += (dram_s - compute_s).max(0.0);
            useful += u;
            issued += i;
            lookups += tile.cache.iter().filter(|&&c| c != 0).count() as u64;
            sig_total += tile.significant.iter().map(|&v| v as u64).sum::<u64>();
            front_total += tile.consumed.iter().map(|&v| v as u64).sum::<u64>();
        }
        out.cache_swap_bytes = extra_swap_bytes;
        let swap_s = self.dram.transfer_time_s(extra_swap_bytes as usize);
        // Swaps are double-buffered too; charge only the tail.
        out.raster_s = out.compute_s + out.exposed_dram_s + swap_s * 0.1;
        out.pe_utilization = if issued > 0 {
            useful as f64 / issued as f64
        } else {
            1.0
        };

        // Energy: compute ops + buffer SRAM traffic + DRAM.
        let e = &self.energy;
        out.energy.nru_compute = front_total as f64 * e.pe_frontend_op
            + sig_total as f64 * e.backend_op;
        out.energy.cache = lookups as f64 * e.cache_lookup;
        // Feature buffer: written once by DMA, read by 64 NRUs' PEs
        // (broadcast reads within an NRU counted once per pixel-consume).
        let sram_bytes = out.feature_bytes as f64
            + front_total as f64 * GAUSSIAN_FEATURE_BYTES as f64
            + (OUTPUT_BUF_BYTES as f64) * tiles.len() as f64 / 10.0;
        out.energy.sram = sram_bytes * e.sram_per_byte;
        out.energy.dram = self
            .dram
            .transfer_energy_j((out.feature_bytes + out.cache_swap_bytes) as usize);
        out
    }

    /// O(1)-per-tile mirror of [`Self::frame`] over tile aggregates —
    /// the admission controller's fast pricing path. Per-pixel counts
    /// are assumed uniform within each tile (exact when they are), with
    /// the tile's recorded maximum bounding the peak-group and
    /// feature-stream terms; aggregates are cache-stripped, so no
    /// lookup cycles are charged.
    pub fn frame_from_aggregates(
        &self,
        tiles: &[TileAggregate],
        extra_swap_bytes: u64,
    ) -> LuminCoreFrame {
        let mut out = LuminCoreFrame::default();
        let mut useful = 0u64;
        let mut issued = 0u64;
        let mut sig_total = 0u64;
        let mut front_total = 0u64;
        let per_nru = self.cfg.pes_per_nru.max(1);
        let nrus = self.cfg.nrus.max(1);
        for t in tiles {
            let px = t.pixels() as usize;
            if px == 0 {
                continue;
            }
            let groups = px.div_ceil(per_nru);
            // When the tile has more pixel groups than NRUs, groups wrap
            // round-robin and the per-NRU times accumulate.
            let passes = groups.div_ceil(nrus) as f64;
            // The tile's time is the *max* over its NRU groups — i.e. a
            // fully-populated group at the tile's mean lane depth.
            // Dividing the sum by `groups * per_nru` would dilute the
            // last, partially-filled group below that maximum and price
            // under the exact path, so charge the full-group depth.
            let front_mean = if self.cfg.sparsity_remap {
                (t.iter_sum as f64 / px as f64).ceil()
            } else {
                f64::from(t.iter_max)
            };
            // The group holding the deepest pixel cannot finish faster
            // than its share of that lane.
            let front_peak = if self.cfg.sparsity_remap {
                (f64::from(t.iter_max) / per_nru as f64).ceil()
            } else {
                f64::from(t.iter_max)
            };
            // Backend of a fully-populated group: per_nru lanes at the
            // tile's mean significance.
            let backend_mean =
                (t.sig_sum as f64 / px as f64 * per_nru as f64).ceil();
            let group_cycles =
                front_mean.max(front_peak).max(backend_mean) + PE_FILL_CYCLES as f64;
            let cycles = (group_cycles * passes).round() as u64;
            let compute_s = cycles as f64 / self.cfg.clock_hz;
            // Feature streaming: same deepest-consumer rule as the exact
            // path — iter_max is recorded, so this term is exact.
            let stream_len = u64::from(t.iter_max);
            let bytes = stream_len.min(t.list_len as u64) * GAUSSIAN_FEATURE_BYTES as u64;
            let chunk = (FEATURE_BUF_BYTES / 2).max(1);
            let n_chunks = (bytes as usize).div_ceil(chunk);
            let dram_s = self.dram.transfer_time_s(bytes as usize)
                + (n_chunks.saturating_sub(1)) as f64 * 1e-9;
            out.cycles += cycles;
            out.compute_s += compute_s;
            out.feature_bytes += bytes;
            out.exposed_dram_s += (dram_s - compute_s).max(0.0);
            useful += t.iter_sum + t.sig_sum;
            issued += (front_mean * per_nru as f64 * groups as f64
                + backend_mean * groups as f64)
                .round() as u64;
            sig_total += t.sig_sum;
            front_total += t.iter_sum;
        }
        out.cache_swap_bytes = extra_swap_bytes;
        let swap_s = self.dram.transfer_time_s(extra_swap_bytes as usize);
        out.raster_s = out.compute_s + out.exposed_dram_s + swap_s * 0.1;
        out.pe_utilization = if issued > 0 {
            useful as f64 / issued as f64
        } else {
            1.0
        };
        let e = &self.energy;
        out.energy.nru_compute =
            front_total as f64 * e.pe_frontend_op + sig_total as f64 * e.backend_op;
        out.energy.cache = 0.0;
        let sram_bytes = out.feature_bytes as f64
            + front_total as f64 * GAUSSIAN_FEATURE_BYTES as f64
            + (OUTPUT_BUF_BYTES as f64) * tiles.len() as f64 / 10.0;
        out.energy.sram = sram_bytes * e.sram_per_byte;
        out.energy.dram = self
            .dram
            .transfer_energy_j((out.feature_bytes + out.cache_swap_bytes) as usize);
        out
    }
}

/// Build per-tile workloads from functional rasterizer outputs.
///
/// `consumed`/`significant` are per-pixel (row-major, width x height);
/// `cache_outcome` is 0/1/2/3 per pixel (none/miss/own-hit/shared-
/// snapshot-hit — any nonzero value is a lookup; provenance does not
/// change the per-lookup timing, only the frame-level contention term
/// charged by the cost model for shared scope).
pub fn tiles_from_stats(
    lists: &[usize],
    tiles_x: usize,
    tiles_y: usize,
    tile_size: usize,
    width: usize,
    height: usize,
    consumed: &[u32],
    significant: &[u32],
    cache_outcome: Option<&[u8]>,
) -> Vec<TileWork> {
    let mut tiles = Vec::with_capacity(tiles_x * tiles_y);
    for ty in 0..tiles_y {
        for tx in 0..tiles_x {
            let mut tw = TileWork {
                list_len: lists[ty * tiles_x + tx] as u32,
                ..Default::default()
            };
            for ly in 0..tile_size {
                let y = ty * tile_size + ly;
                if y >= height {
                    break;
                }
                for lx in 0..tile_size {
                    let x = tx * tile_size + lx;
                    if x >= width {
                        break;
                    }
                    let off = y * width + x;
                    tw.consumed.push(consumed[off]);
                    tw.significant.push(significant[off]);
                    tw.cache.push(cache_outcome.map(|c| c[off]).unwrap_or(0));
                }
            }
            tiles.push(tw);
        }
    }
    tiles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_tile(px: usize, consumed: u32, sig: u32, cache: u8) -> TileWork {
        TileWork {
            list_len: consumed,
            consumed: vec![consumed; px],
            significant: vec![sig; px],
            cache: vec![cache; px],
        }
    }

    #[test]
    fn empty_tile_is_free() {
        let sim = LuminCoreSim::paper_default();
        let (c, u, i) = sim.tile_cycles(&TileWork::default());
        assert_eq!((c, u, i), (0, 0, 0));
    }

    #[test]
    fn frontend_bound_tile() {
        // 256 px, 1000 consumed each, few significant: frontend-bound.
        let sim = LuminCoreSim::paper_default();
        let tile = uniform_tile(256, 1000, 10, 0);
        let (cycles, _, _) = sim.tile_cycles(&tile);
        // With remap: per NRU 4 px x 1000 / 4 PEs = 1000 cycles + fill.
        assert_eq!(cycles, 1000 + PE_FILL_CYCLES);
    }

    #[test]
    fn backend_bound_tile() {
        // Dense significant load saturates the shared backend.
        let sim = LuminCoreSim::paper_default();
        let tile = uniform_tile(256, 500, 400, 0);
        let (cycles, _, _) = sim.tile_cycles(&tile);
        // Backend: 4 px x 400 sig = 1600/cycle-per-NRU > frontend 500.
        assert_eq!(cycles, 1600 + PE_FILL_CYCLES);
    }

    #[test]
    fn remap_beats_normal_mode_under_imbalance() {
        let mut sim = LuminCoreSim::paper_default();
        // Imbalanced pixels: one long, three short per NRU group.
        let mut tile = TileWork {
            list_len: 1000,
            consumed: Vec::new(),
            significant: vec![0; 256],
            cache: vec![2; 256],
        };
        for i in 0..256 {
            tile.consumed.push(if i % 4 == 0 { 1000 } else { 50 });
        }
        sim.cfg.sparsity_remap = true;
        let (remap, _, _) = sim.tile_cycles(&tile);
        sim.cfg.sparsity_remap = false;
        let (normal, _, _) = sim.tile_cycles(&tile);
        assert!(
            remap < normal,
            "remap {remap} should beat normal {normal} under imbalance"
        );
        // Remap: (1000 + 3*50)/4 ~ 288 vs normal max = 1000.
        assert!(remap < 400 + PE_FILL_CYCLES + 256);
    }

    #[test]
    fn utilization_improves_with_remap() {
        let mut sim = LuminCoreSim::paper_default();
        let mut tile = uniform_tile(256, 100, 5, 1);
        for (i, c) in tile.consumed.iter_mut().enumerate() {
            if i % 4 != 0 {
                *c = 10; // RC hits cut 3 of 4 pixels short
            }
        }
        sim.cfg.sparsity_remap = false;
        let f_norm = sim.frame(std::slice::from_ref(&tile), 0);
        sim.cfg.sparsity_remap = true;
        let f_remap = sim.frame(std::slice::from_ref(&tile), 0);
        assert!(f_remap.pe_utilization > f_norm.pe_utilization);
        assert!(f_remap.raster_s <= f_norm.raster_s);
    }

    #[test]
    fn frame_time_scales_with_work() {
        let sim = LuminCoreSim::paper_default();
        let light: Vec<TileWork> = (0..16).map(|_| uniform_tile(256, 100, 10, 0)).collect();
        let heavy: Vec<TileWork> = (0..16).map(|_| uniform_tile(256, 1000, 100, 0)).collect();
        let fl = sim.frame(&light, 0);
        let fh = sim.frame(&heavy, 0);
        assert!(fh.raster_s > 5.0 * fl.raster_s);
        assert!(fh.energy.total() > 5.0 * fl.energy.total());
    }

    #[test]
    fn double_buffering_hides_memory_when_compute_bound() {
        let sim = LuminCoreSim::paper_default();
        // Heavy compute, small list: memory fully hidden.
        let tile = uniform_tile(256, 2000, 1500, 0);
        let f = sim.frame(std::slice::from_ref(&tile), 0);
        assert_eq!(f.exposed_dram_s, 0.0);
    }

    #[test]
    fn memory_bound_tile_exposes_dram_time() {
        let sim = LuminCoreSim::paper_default();
        // One pixel consumes a huge list while the rest are trivially
        // insignificant: the stream must run to the deepest consumer,
        // but compute (spread over 4 PEs by remapping) stays small.
        let mut consumed = vec![1u32; 256];
        consumed[0] = 200_000;
        let tile = TileWork {
            list_len: 200_000,
            consumed,
            significant: vec![0; 256],
            cache: vec![0; 256],
        };
        let f = sim.frame(std::slice::from_ref(&tile), 0);
        assert!(f.exposed_dram_s > 0.0);
    }

    #[test]
    fn rc_hits_cut_feature_traffic() {
        // When every pixel of a tile hits early, the stream stops early.
        let sim = LuminCoreSim::paper_default();
        let deep = uniform_tile(256, 1000, 50, 0);
        let hit = uniform_tile(256, 60, 5, 2);
        let f_deep = sim.frame(std::slice::from_ref(&deep), 0);
        let f_hit = sim.frame(std::slice::from_ref(&hit), 0);
        assert!(f_hit.feature_bytes < f_deep.feature_bytes / 10);
    }

    #[test]
    fn cache_lookups_cost_cycles() {
        let sim = LuminCoreSim::paper_default();
        let no_rc = uniform_tile(256, 100, 10, 0);
        let with_rc = uniform_tile(256, 100, 10, 1);
        let (c0, _, _) = sim.tile_cycles(&no_rc);
        let (c1, _, _) = sim.tile_cycles(&with_rc);
        assert!(c1 > c0);
    }

    #[test]
    fn paper_scale_raster_speedup_over_gpu() {
        // Anchor: paper reports LuminCore accelerates Rasterization ~6.4x
        // vs the mobile GPU. Feed both models the same paper-scale
        // statistics and compare.
        use crate::sim::gpu::{GpuModel, WarpAggregates};
        let sim = LuminCoreSim::paper_default();
        let n_tiles = (800 / 16) * (800 / 16);
        let tiles: Vec<TileWork> =
            (0..n_tiles).map(|_| uniform_tile(256, 1000, 100, 0)).collect();
        let f = sim.frame(&tiles, 0);

        let gpu = GpuModel::xavier_volta();
        let px = 800 * 800;
        let warps = (px / 32) as u64;
        let agg = WarpAggregates {
            warp_rounds: warps as f64 * 1100.0,
            blend_rounds: warps as f64 * 1050.0,
            active_front_lane_rounds: px as f64 * 1000.0,
            active_blend_lane_rounds: px as f64 * 100.0,
            warps,
        };
        let gpu_raster = gpu.raster_time_s(&agg);
        let speedup = gpu_raster / f.raster_s;
        assert!(
            speedup > 3.0 && speedup < 13.0,
            "raster speedup {speedup} (paper: ~6.4x)"
        );
    }
}
