//! Hardware cost models and the cycle-accurate LuminCore simulator.
//!
//! * [`cost`]      — the pluggable [`cost::CostModel`] /
//!   [`cost::FrontendCostModel`] trait seams the coordinator composes;
//!   implemented by the three hardware models below.
//! * [`gpu`]       — mobile-Volta SIMT model (warp divergence, stage
//!   times), calibrated to the paper's published anchors.
//! * [`lumincore`] — cycle-accurate NRU array + buffers + LuminCache
//!   timing, with sparsity-aware remapping.
//! * [`gscore`]    — the GSCore comparator (CCU/GSU/rasterizer).
//! * [`dram`]      — LPDDR3-1600 x4 bandwidth/latency/energy.
//! * [`energy`]    — 12 nm component energy constants (25:1 DRAM:SRAM).

pub mod cost;
pub mod dram;
pub mod energy;
pub mod gpu;
pub mod gscore;
pub mod lumincore;

pub use cost::{CostModel, FrontendCostModel, RasterCost};
