//! Measurement harness for `cargo bench` (the `criterion` substitute).
//!
//! Each bench target is a plain `harness = false` binary that builds a
//! [`Runner`], registers closures, and calls [`Runner::finish`]. The
//! runner warms up, runs timed batches until a wall budget is spent, and
//! reports min/median/mean per iteration plus a throughput column.
//!
//! Environment:
//! * `LUMINA_BENCH_QUICK=1` — short measurement budget.
//! * `LUMINA_BENCH_SMOKE=1` — CI smoke mode: benches shrink their scenes
//!   and the quick budget is implied.
//! * `LUMINA_BENCH_JSON=<path>` — additionally write the measurements as
//!   JSON (the `BENCH_*.json` artifacts the CI regression gate diffs).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

/// Bench runner: registers and executes named closures.
pub struct Runner {
    pub label: String,
    budget: Duration,
    warmup: Duration,
    results: Vec<Measurement>,
    filter: Option<String>,
}

impl Runner {
    pub fn new(label: &str) -> Self {
        // `cargo bench -- <filter>` passes the filter as an argument;
        // `--bench` is also passed by cargo and must be ignored.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        let quick = std::env::var("LUMINA_BENCH_QUICK").is_ok()
            || std::env::var("LUMINA_BENCH_SMOKE").is_ok();
        Runner {
            label: label.to_string(),
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            results: Vec::new(),
            filter,
        }
    }

    /// Whether `name` passes the CLI filter — lets callers skip the
    /// *work* behind a filtered-out measurement (e.g. the pool run a
    /// metric is computed from), not just its registration.
    pub fn enabled(&self, name: &str) -> bool {
        self.filter.as_ref().is_none_or(|f| name.contains(f.as_str()))
    }

    /// Time `f` repeatedly; `f` should perform one logical iteration and
    /// return a value (kept opaque to the optimizer via `black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        if !self.enabled(name) {
            return;
        }
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Choose batch size so one batch is ~10ms.
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let batch = ((0.01 / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let timed = Instant::now();
        let mut total_iters = 0u64;
        while timed.elapsed() < self.budget || samples.len() < 5 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed();
            samples.push(dt / batch as u32);
            total_iters += batch;
            if samples.len() >= 200 {
                break;
            }
        }
        samples.sort();
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let m = Measurement { name: name.to_string(), iters: total_iters, min, median, mean };
        println!(
            "{:<48} {:>12} {:>12} {:>12}  ({} iters)",
            m.name,
            fmt_dur(m.min),
            fmt_dur(m.median),
            fmt_dur(m.mean),
            m.iters
        );
        self.results.push(m);
    }

    /// Record a non-timing scalar as a pseudo-measurement: `value`
    /// lands in the ns fields of the JSON schema unchanged. Used for
    /// machine-independent invariants the bench gate checks *within*
    /// one run (e.g. the shared-vs-private cache hit rates in ppm).
    /// Name such entries `metric/...` — the gate's cross-run throughput
    /// diff skips that prefix, since these are not timings.
    pub fn metric(&mut self, name: &str, value: u64) {
        if !self.enabled(name) {
            return;
        }
        let d = Duration::from_nanos(value);
        println!("{name:<48} {value:>12} (metric value, not a timing)");
        self.results.push(Measurement {
            name: name.to_string(),
            iters: 1,
            min: d,
            median: d,
            mean: d,
        });
    }

    /// Print the header row (call before the first bench).
    pub fn header(&self) {
        println!("== bench: {} ==", self.label);
        println!("{:<48} {:>12} {:>12} {:>12}", "name", "min", "median", "mean");
    }

    /// Finish: returns results for programmatic use. When
    /// `LUMINA_BENCH_JSON` names a path, the measurements are also
    /// written there as JSON for the CI regression gate.
    pub fn finish(self) -> Vec<Measurement> {
        println!("== {} done: {} benchmarks ==", self.label, self.results.len());
        if let Ok(path) = std::env::var("LUMINA_BENCH_JSON") {
            match std::fs::write(&path, results_json(&self.label, &self.results)) {
                Ok(()) => println!("wrote {path}"),
                Err(e) => eprintln!("failed to write {path}: {e}"),
            }
        }
        self.results
    }
}

/// Serialize measurements as the `BENCH_*.json` schema: a label plus
/// one `{name, iters, min_ns, median_ns, mean_ns}` entry per benchmark.
/// Hand-rolled (no serde in the offline vendor set); names are escaped
/// for the JSON string context.
pub fn results_json(label: &str, results: &[Measurement]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"label\": \"{}\",\n", escape_json(label)));
    out.push_str("  \"results\": [\n");
    for (i, m) in results.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"min_ns\": {}, \"median_ns\": {}, \
             \"mean_ns\": {}}}{}\n",
            escape_json(&m.name),
            m.iters,
            m.min.as_nanos(),
            m.median.as_nanos(),
            m.mean.as_nanos(),
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn escape_json(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Human-friendly duration formatting.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_schema_stable() {
        let results = vec![Measurement {
            name: "pool_depth1/2x4frames".into(),
            iters: 12,
            min: Duration::from_nanos(1000),
            median: Duration::from_nanos(1500),
            mean: Duration::from_nanos(1600),
        }];
        let s = results_json("sessions", &results);
        assert!(s.contains("\"label\": \"sessions\""), "{s}");
        assert!(s.contains("\"median_ns\": 1500"), "{s}");
        assert!(s.contains("pool_depth1/2x4frames"), "{s}");
        // Quotes and control characters stay inside the string context.
        assert!(results_json("a\"b", &[]).contains("a\\\"b"));
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(3)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
