//! Tiny CLI argument parser (the `clap` substitute).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, `--key value` options (repeatable keys
/// collect), boolean `--flags`, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

/// Keys that take a value (everything else after `--` is a flag).
pub fn parse(argv: &[String], value_keys: &[&str]) -> Args {
    let mut args = Args::default();
    let mut iter = argv.iter().peekable();
    while let Some(a) = iter.next() {
        if let Some(key) = a.strip_prefix("--") {
            // --key=value form.
            if let Some((k, v)) = key.split_once('=') {
                args.options.entry(k.to_string()).or_default().push(v.to_string());
                continue;
            }
            if value_keys.contains(&key) {
                if let Some(v) = iter.next() {
                    args.options.entry(key.to_string()).or_default().push(v.clone());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.flags.push(key.to_string());
            }
        } else if args.subcommand.is_none() && args.positionals.is_empty() {
            args.subcommand = Some(a.clone());
        } else {
            args.positionals.push(a.clone());
        }
    }
    args
}

impl Args {
    /// Last value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable `--key`.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.options.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Presence of a boolean `--flag`.
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    /// Parse `--key` as `T`, with a default.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse `--key` as `T`, erroring on a malformed value instead of
    /// silently falling back to a default (`--seed banana` should fail
    /// loudly, not quietly run seed 0). `Ok(None)` when absent.
    pub fn try_parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("invalid --{key} value: {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(
            &argv(&["render", "--config", "x.toml", "--verbose", "pos1"]),
            &["config"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("render"));
        assert_eq!(a.get("config"), Some("x.toml"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["pos1"]);
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = parse(&argv(&["run", "--set=a=1", "--set", "b=2"]), &["set"]);
        assert_eq!(a.get_all("set"), &["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn parsed_with_default() {
        let a = parse(&argv(&["x", "--n", "12"]), &["n"]);
        assert_eq!(a.get_parsed("n", 5usize), 12);
        assert_eq!(a.get_parsed("missing", 5usize), 5);
    }

    #[test]
    fn try_parsed_rejects_malformed_values() {
        let a = parse(&argv(&["x", "--seed", "7", "--epochs", "banana"]), &["seed", "epochs"]);
        assert_eq!(a.try_parsed::<u64>("seed").unwrap(), Some(7));
        assert_eq!(a.try_parsed::<u64>("missing").unwrap(), None);
        let err = a.try_parsed::<usize>("epochs").unwrap_err().to_string();
        assert!(err.contains("--epochs"), "error names the key: {err}");
    }
}
