//! Minimal TOML-subset parser/serializer (the `serde`+`toml` substitute).
//!
//! Supports the subset the config system needs: top-level key/values,
//! `[section]` and `[section.sub]` tables, strings, integers, floats,
//! booleans, and flat arrays. No inline tables, no dates, no multi-line
//! strings.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    String(String),
    Integer(i64),
    Float(f64),
    Boolean(bool),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Integer(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Boolean(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_table_mut(&mut self) -> Option<&mut BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    /// Walk a dotted path.
    pub fn get_path(&self, path: &str) -> Option<&Value> {
        let mut node = self;
        for part in path.split('.') {
            node = node.as_table()?.get(part)?;
        }
        Some(node)
    }

    /// Insert at a dotted path, creating intermediate tables.
    ///
    /// An empty path or a path with an empty segment (`""`, `"a..b"`,
    /// `"a."`) is a `ParseError`, not a panic.
    pub fn set_path(&mut self, path: &str, value: Value) -> Result<(), ParseError> {
        let parts: Vec<&str> = path.split('.').collect();
        if parts.iter().any(|p| p.is_empty()) {
            return Err(ParseError::new(
                0,
                format!("empty segment in key path {path:?}"),
            ));
        }
        let Some((leaf, parents)) = parts.split_last() else {
            return Err(ParseError::new(0, "empty key path".into()));
        };
        let mut node = self;
        for part in parents {
            let table = node
                .as_table_mut()
                .ok_or_else(|| ParseError::new(0, format!("{part} is not a table")))?;
            node = table
                .entry(part.to_string())
                .or_insert_with(|| Value::Table(BTreeMap::new()));
        }
        let table = node
            .as_table_mut()
            .ok_or_else(|| ParseError::new(0, "leaf parent is not a table".into()))?;
        table.insert(leaf.to_string(), value);
        Ok(())
    }
}

/// Parse error with a 1-based line number.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl ParseError {
    fn new(line: usize, message: String) -> Self {
        ParseError { line, message }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TOML parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a TOML-subset document into a root table value.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut root = Value::Table(BTreeMap::new());
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let ln = lineno + 1;
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| ParseError::new(ln, "unterminated section header".into()))?
                .trim();
            if name.is_empty() || name.starts_with('[') {
                return Err(ParseError::new(ln, format!("bad section header: {line}")));
            }
            section = name.to_string();
            // Materialize the table even if empty.
            root.set_path(&section, Value::Table(BTreeMap::new()))
                .map_err(|e| ParseError::new(ln, e.message))?;
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| ParseError::new(ln, format!("expected key = value: {line}")))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(ParseError::new(ln, "empty key".into()));
        }
        let value = parse_value(val.trim(), ln)?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        root.set_path(&path, value)
            .map_err(|e| ParseError::new(ln, e.message))?;
    }
    Ok(root)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return Err(ParseError::new(line, "empty value".into()));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| ParseError::new(line, "unterminated string".into()))?;
        return Ok(Value::String(unescape(inner)));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| ParseError::new(line, "unterminated array".into()))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for item in split_array_items(inner) {
            items.push(parse_value(item.trim(), line)?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Boolean(true)),
        "false" => return Ok(Value::Boolean(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Integer(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError::new(line, format!("cannot parse value: {s}")))
}

fn split_array_items(s: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

/// Serialize a root table to TOML text (sections for nested tables).
pub fn serialize(root: &Value) -> String {
    let mut out = String::new();
    if let Value::Table(t) = root {
        // Scalars first.
        for (k, v) in t {
            if !matches!(v, Value::Table(_)) {
                out.push_str(&format!("{k} = {}\n", fmt_scalar(v)));
            }
        }
        for (k, v) in t {
            if let Value::Table(sub) = v {
                serialize_section(k, sub, &mut out);
            }
        }
    }
    out
}

fn serialize_section(path: &str, table: &BTreeMap<String, Value>, out: &mut String) {
    out.push_str(&format!("\n[{path}]\n"));
    for (k, v) in table {
        if !matches!(v, Value::Table(_)) {
            out.push_str(&format!("{k} = {}\n", fmt_scalar(v)));
        }
    }
    for (k, v) in table {
        if let Value::Table(sub) = v {
            serialize_section(&format!("{path}.{k}"), sub, out);
        }
    }
}

fn fmt_scalar(v: &Value) -> String {
    match v {
        Value::String(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Value::Integer(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 && f.abs() < 1e15 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Boolean(b) => b.to_string(),
        Value::Array(items) => {
            let inner: Vec<String> = items.iter().map(fmt_scalar).collect();
            format!("[{}]", inner.join(", "))
        }
        Value::Table(_) => unreachable!("tables serialized as sections"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let v = parse(
            r#"
            # comment
            name = "lumina"   # trailing comment
            count = 42
            ratio = 2.5
            on = true
            tags = [1, 2, 3]

            [scene]
            class = "synthetic-small"
            seed = 7

            [scene.nested]
            depth = 2
            "#,
        )
        .unwrap();
        assert_eq!(v.get_path("name").unwrap().as_str(), Some("lumina"));
        assert_eq!(v.get_path("count").unwrap().as_int(), Some(42));
        assert_eq!(v.get_path("ratio").unwrap().as_float(), Some(2.5));
        assert_eq!(v.get_path("on").unwrap().as_bool(), Some(true));
        assert_eq!(v.get_path("scene.class").unwrap().as_str(), Some("synthetic-small"));
        assert_eq!(v.get_path("scene.nested.depth").unwrap().as_int(), Some(2));
        match v.get_path("tags").unwrap() {
            Value::Array(items) => assert_eq!(items.len(), 3),
            _ => panic!("tags not an array"),
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"
            top = 1
            [a]
            x = "hi"
            y = 2.5
            [a.b]
            z = false
        "#;
        let v = parse(src).unwrap();
        let text = serialize(&v);
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("x = \"unterminated").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let v = parse("s = \"a#b\"").unwrap();
        assert_eq!(v.get_path("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn set_path_creates_tables() {
        let mut v = Value::Table(BTreeMap::new());
        v.set_path("a.b.c", Value::Integer(5)).unwrap();
        assert_eq!(v.get_path("a.b.c").unwrap().as_int(), Some(5));
    }

    #[test]
    fn set_path_rejects_empty_segments_without_panicking() {
        let mut v = Value::Table(BTreeMap::new());
        assert!(v.set_path("", Value::Integer(1)).is_err());
        assert!(v.set_path("a..b", Value::Integer(1)).is_err());
        assert!(v.set_path("a.", Value::Integer(1)).is_err());
        assert!(v.set_path(".a", Value::Integer(1)).is_err());
        // The table is untouched by the failed inserts.
        assert!(v.as_table().unwrap().is_empty());
    }

    #[test]
    fn int_vs_float() {
        let v = parse("i = 3\nf = 3.0").unwrap();
        assert!(matches!(v.get_path("i").unwrap(), Value::Integer(3)));
        assert!(matches!(v.get_path("f").unwrap(), Value::Float(_)));
        // as_float coerces ints.
        assert_eq!(v.get_path("i").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn negative_numbers() {
        let v = parse("a = -4\nb = -0.5").unwrap();
        assert_eq!(v.get_path("a").unwrap().as_int(), Some(-4));
        assert_eq!(v.get_path("b").unwrap().as_float(), Some(-0.5));
    }
}
