//! In-crate substrates replacing external dependencies.
//!
//! The build image is fully offline; its vendored crate set covers only
//! the `xla` closure + `anyhow`. Everything else a framework of this
//! shape normally pulls in is implemented here (DESIGN.md §8):
//!
//! * [`prng`]    — deterministic PCG32 PRNG (replaces `rand`/`rand_chacha`)
//! * [`par`]     — scoped-thread data parallelism (replaces `rayon`)
//! * [`minitoml`]— TOML-subset parser/serializer (replaces `serde`+`toml`)
//! * [`cli`]     — argument parsing (replaces `clap`)
//! * [`bench`]   — measurement harness for `cargo bench` (replaces `criterion`)
//! * [`testing`] — temp files + property-testing helpers (replaces
//!   `tempfile`/`proptest`)

pub mod bench;
pub mod cli;
pub mod minitoml;
pub mod par;
pub mod prng;
pub mod testing;
