//! Scoped-thread data parallelism (the `rayon` substitute).
//!
//! Two primitives cover every hot path in the crate: parallel map over an
//! index range, and parallel iteration over mutable chunks. Work is split
//! into `num_threads()` contiguous blocks — rasterization and projection
//! workloads are statically balanced enough that work stealing isn't
//! worth the complexity.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

static CACHED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread budget override (0 = none). Lets nested parallelism —
    /// e.g. a `SessionPool` worker whose pipeline stages parallelize —
    /// clamp only its own thread without mutating the process-global
    /// budget (which would leak to unrelated threads on panic).
    static LOCAL_BUDGET: Cell<usize> = Cell::new(0);
}

/// Number of worker threads (overridable with `LUMINA_THREADS`,
/// [`set_num_threads`], or — on the current thread only — a
/// [`ThreadBudgetGuard`]).
pub fn num_threads() -> usize {
    let local = LOCAL_BUDGET.with(|c| c.get());
    if local != 0 {
        return local;
    }
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("LUMINA_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Override the worker-thread count at runtime (`0` resets to the
/// `LUMINA_THREADS`/auto-detect default). Primarily for determinism
/// tests, which must compare 1-thread and many-thread runs within one
/// process — the env var is only read once.
pub fn set_num_threads(n: usize) {
    CACHED.store(n, Ordering::Relaxed);
}

/// RAII guard for a *thread-local* worker budget: while alive, `par_*`
/// calls issued from the current thread see `n` workers; dropping it —
/// including during a panic unwind — restores the previous value.
///
/// This is how nested parallelism splits the machine: each outer worker
/// holds a guard for its share, and the process-global budget is never
/// mutated, so a panicking worker cannot leak a clamped thread count to
/// the rest of the process.
pub struct ThreadBudgetGuard {
    prev: usize,
}

/// Install a thread-local budget of `n` workers for the current thread,
/// restored when the returned guard drops.
pub fn local_budget_guard(n: usize) -> ThreadBudgetGuard {
    let prev = LOCAL_BUDGET.with(|c| c.replace(n.max(1)));
    ThreadBudgetGuard { prev }
}

impl Drop for ThreadBudgetGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        LOCAL_BUDGET.with(|c| c.set(prev));
    }
}

/// Split a thread budget of `total` across `workers` outer workers with
/// no stranded threads: each worker gets at least one thread, and the
/// remainder of `total / workers` is distributed one-per-worker from the
/// front (8 threads / 3 workers -> [3, 3, 2], not [2, 2, 2]).
///
/// When `total >= workers` the shares sum to exactly `total`; when
/// `total < workers` every worker still gets 1 (mild oversubscription
/// beats idle sessions).
pub fn split_budget(total: usize, workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let base = total / workers;
    let rem = total % workers;
    (0..workers).map(|i| (base + usize::from(i < rem)).max(1)).collect()
}

/// Split a thread budget across the two concurrently-running stages of
/// a double-buffered frame slot: (raster, frontend). The raster stage —
/// typically the heavier — takes the remainder on odd budgets; both
/// sides get at least one thread.
pub fn split_pair(total: usize) -> (usize, usize) {
    let shares = split_budget(total, 2);
    (shares[0], shares[1])
}

/// Parallel map over `0..n`: returns `Vec<T>` with `f(i)` at index `i`.
///
/// Cheap per-item closures (projection-style, n in the tens of
/// thousands) get a static contiguous split; small-n maps (n < 4096)
/// use dynamic work claiming so imbalanced per-item costs (per-tile
/// rasterization!) still load-balance.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    if n < 4096 {
        // Dynamic claiming: one item at a time (items are expensive and
        // imbalanced, e.g. image tiles).
        let next = AtomicUsize::new(0);
        let ptr = SendPtr::new(out.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let next = &next;
                let f = &f;
                let ptr = ptr;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: `fetch_add` hands each index `i < n` to
                    // exactly one worker, so no two workers ever write
                    // the same slot; `out` was resized to `n` slots
                    // before the scope, so `add(i)` stays in bounds; and
                    // the scope's borrow of `out` keeps the allocation
                    // alive until every worker joins.
                    unsafe { *ptr.get().add(i) = Some(f(i)) };
                });
            }
        });
    } else {
        let chunk = n.div_ceil(threads);
        std::thread::scope(|scope| {
            for (t, slot) in out.chunks_mut(chunk).enumerate() {
                let f = &f;
                scope.spawn(move || {
                    let base = t * chunk;
                    for (j, s) in slot.iter_mut().enumerate() {
                        *s = Some(f(base + j));
                    }
                });
            }
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Parallel for-each over mutable chunks of `data` of size `chunk_size`;
/// `f(chunk_index, chunk)` runs on worker threads.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_size: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_size > 0);
    let n_chunks = data.len().div_ceil(chunk_size.max(1));
    if num_threads() <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(i, c);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Collect raw chunk bounds first so workers can claim them atomically.
    let chunks: Vec<(usize, usize)> = (0..n_chunks)
        .map(|i| (i * chunk_size, ((i + 1) * chunk_size).min(data.len())))
        .collect();
    let ptr = SendPtr::new(data.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..num_threads().min(n_chunks) {
            let next = &next;
            let chunks = &chunks;
            let f = &f;
            let ptr = ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= chunks.len() {
                    break;
                }
                let (lo, hi) = chunks[i];
                let base = ptr.get();
                // SAFETY: the `chunks` ranges tile `0..data.len()`
                // without overlap (`[i*cs, min((i+1)*cs, len))`), and
                // `fetch_add` hands each range to exactly one worker, so
                // the reconstituted sub-slices are pairwise disjoint and
                // in bounds; the scope's borrow of `data` keeps the
                // allocation alive until every worker joins.
                let slice = unsafe { std::slice::from_raw_parts_mut(base.add(lo), hi - lo) };
                f(i, slice);
            });
        }
    });
}

/// Parallel for-each over disjoint index blocks `0..n` in `blocks` pieces;
/// `f(block_index, range)`.
pub fn par_blocks<F>(n: usize, blocks: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let blocks = blocks.max(1);
    let next = AtomicUsize::new(0);
    let chunk = n.div_ceil(blocks);
    std::thread::scope(|scope| {
        for _ in 0..num_threads().min(blocks) {
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let b = next.fetch_add(1, Ordering::Relaxed);
                if b >= blocks {
                    break;
                }
                let lo = b * chunk;
                let hi = ((b + 1) * chunk).min(n);
                if lo < hi {
                    f(b, lo..hi);
                }
            });
        }
    });
}

/// Deterministic task claimer — the claim half of the crate's
/// claim/write publication pattern, factored out of the dynamic-claim
/// loops above for the pool-wide stage scheduler
/// (`coordinator::steal`). Workers call [`Self::next`] until it returns
/// `None`: the `fetch_add` hands each ID in `0..len` to exactly one
/// worker, in ascending order across the claim sequence, so the lowest
/// unclaimed task always goes to the next idle worker. Claiming carries
/// no result publication by itself — writers publish their slots to the
/// coordinating thread through the enclosing `thread::scope` join,
/// exactly as in [`par_map`]'s dynamic-claim path.
pub struct TaskClaimer {
    next: AtomicUsize,
    len: usize,
}

impl TaskClaimer {
    /// A claimer over task IDs `0..len`.
    pub fn new(len: usize) -> Self {
        TaskClaimer { next: AtomicUsize::new(0), len }
    }

    /// Claim the lowest unclaimed task ID; `None` once all are claimed.
    pub fn next(&self) -> Option<usize> {
        // Relaxed suffices: the claim only needs RMW uniqueness (a total
        // modification order on one atomic); publication of the claimed
        // task's results happens-before via the scope join.
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        (i < self.len).then_some(i)
    }

    /// Number of task IDs this claimer hands out.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Raw-pointer wrapper that crosses `thread::scope` closure boundaries.
///
/// This is the one sanctioned way for the crate's parallel writers (the
/// claim loops above, the scatter pass in `pipeline::sort`) to share a
/// base pointer across workers. Every user must uphold the contract in
/// the `Send`/`Sync` impls below: all dereferences go through
/// `base.add(k)` for index sets proven pairwise disjoint *before* the
/// workers start (atomic claim counters or exclusive prefix sums), and
/// only inside a `thread::scope` whose borrow keeps the allocation
/// alive until every worker joins.
pub(crate) struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a base pointer for cross-worker sharing (see the type-level
    /// contract).
    pub(crate) fn new(ptr: *mut T) -> Self {
        SendPtr(ptr)
    }

    /// Accessor (method receiver forces whole-struct closure capture, so
    /// the `Send` impl on the wrapper applies rather than the raw field).
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}
// SAFETY: sending the wrapper only moves the pointer *value* to another
// worker. Dereferences stay sound because every user writes through
// disjoint index sets — par_map's atomic counter hands each index to
// exactly one claimant, par_chunks_mut's precomputed (lo, hi) ranges
// never overlap, and the sort scatter's exclusive prefix sums give each
// (chunk, tile) pair its own segment — and the enclosing thread::scope
// borrows the underlying buffer, so it outlives every worker. `T: Send`
// is required because the pointee is handed to another thread.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: `&SendPtr` only exposes the pointer value via `get()`; shared
// references to the wrapper enable no aliased *writes* by themselves.
// Mutation soundness rests on the same disjoint-index discipline as the
// `Send` impl — two workers holding copies never dereference the same
// offset.
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    // Sizes shrink under miri (interpreted execution is ~1000x slower)
    // while still crossing the dynamic-claim / static-split boundary at
    // 4096 and exercising multi-chunk claiming.
    const MAP_N: usize = if cfg!(miri) { 4200 } else { 10_000 };
    const CHUNKS_N: usize = if cfg!(miri) { 4100 } else { 100_000 };
    const BLOCKS_N: usize = if cfg!(miri) { 600 } else { 5000 };

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(MAP_N, |i| i * i);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_dynamic_claim_path_matches_serial() {
        // n < 4096 takes the atomic-claim raw-slot path regardless of
        // the miri scaling above.
        let got = par_map(1500, |i| i * 3 + 1);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i * 3 + 1);
        }
    }

    #[test]
    fn par_map_small_input() {
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut data = vec![0u32; CHUNKS_N];
        par_chunks_mut(&mut data, 1024, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 1024 + j) as u32;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
    }

    #[test]
    fn par_chunks_mut_uneven_tail() {
        let mut data = vec![0u8; 1000];
        par_chunks_mut(&mut data, 333, |_ci, chunk| {
            for v in chunk.iter_mut() {
                *v = 7;
            }
        });
        assert!(data.iter().all(|&v| v == 7));
    }

    #[test]
    fn split_budget_strands_no_workers() {
        // The 8/3 case from the session-pool bug: the naive total/outer
        // split used only 6 of 8 threads.
        assert_eq!(split_budget(8, 3), vec![3, 3, 2]);
        assert_eq!(split_budget(8, 8), vec![1; 8]);
        assert_eq!(split_budget(9, 4), vec![3, 2, 2, 2]);
        for (total, workers) in [(8, 3), (16, 5), (7, 2), (12, 12), (64, 7)] {
            let shares = split_budget(total, workers);
            assert_eq!(shares.len(), workers);
            assert_eq!(shares.iter().sum::<usize>(), total, "{total}/{workers}");
            assert!(shares.iter().all(|&s| s >= 1));
        }
        // Oversubscribed: everyone still gets a thread.
        assert_eq!(split_budget(2, 5), vec![1; 5]);
    }

    #[test]
    fn split_pair_covers_budget() {
        assert_eq!(split_pair(8), (4, 4));
        assert_eq!(split_pair(5), (3, 2), "raster takes the remainder");
        assert_eq!(split_pair(2), (1, 1));
        assert_eq!(split_pair(1), (1, 1), "both stages always get a thread");
    }

    #[test]
    fn local_budget_guard_overrides_and_restores() {
        let ambient = num_threads();
        {
            let _g = local_budget_guard(3);
            assert_eq!(num_threads(), 3);
            {
                let _inner = local_budget_guard(2);
                assert_eq!(num_threads(), 2);
            }
            assert_eq!(num_threads(), 3);
        }
        assert_eq!(num_threads(), ambient);
    }

    #[test]
    fn local_budget_guard_restores_on_panic() {
        let ambient = num_threads();
        let result = std::panic::catch_unwind(|| {
            let _g = local_budget_guard(1);
            panic!("injected");
        });
        assert!(result.is_err());
        assert_eq!(num_threads(), ambient, "budget leaked across a panic");
    }

    #[test]
    fn local_budget_is_thread_local() {
        // An implausible-as-ambient value; a fresh thread must not see it.
        let _g = local_budget_guard(1301);
        assert_eq!(num_threads(), 1301);
        let seen = std::thread::spawn(num_threads).join().unwrap();
        assert_ne!(seen, 1301, "local budget leaked to a fresh thread");
    }

    #[test]
    fn task_claimer_partitions_ids_exactly_once() {
        let claimer = TaskClaimer::new(100);
        assert_eq!(claimer.len(), 100);
        assert!(!claimer.is_empty());
        let hits: Vec<std::sync::atomic::AtomicU32> =
            (0..100).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let claimer = &claimer;
                let hits = &hits;
                scope.spawn(move || {
                    while let Some(i) = claimer.next() {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(claimer.next(), None, "drained claimer stays drained");
        assert!(TaskClaimer::new(0).is_empty());
        assert_eq!(TaskClaimer::new(0).next(), None);
    }

    #[test]
    fn par_blocks_covers_range() {
        let hits: Vec<std::sync::atomic::AtomicU32> =
            (0..BLOCKS_N).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        par_blocks(BLOCKS_N, 16, |_b, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
