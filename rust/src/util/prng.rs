//! Deterministic PRNG: PCG32 (O'Neill 2014) + distribution helpers.
//!
//! Replaces `rand`/`rand_chacha` in the offline build. Streams are fully
//! determined by `(seed, stream)` so every scene/trajectory generator is
//! reproducible across runs and platforms.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with a seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed with a single value (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform usize in [0, n) (n > 0), via Lemire's method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Uniform u32 in [lo, hi).
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below((hi - lo) as usize) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// True with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut set = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if set.contains(&t) { j } else { t };
            set.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::seeded(4);
        let mean: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 100_000;
        let samples: Vec<f32> = (0..n).map(|_| r.gauss()).collect();
        let mean: f64 = samples.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            samples.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Pcg32::seeded(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(8);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
