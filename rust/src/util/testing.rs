//! Test support: temp paths and property-testing helpers (the
//! `tempfile`/`proptest` substitute).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use super::prng::Pcg32;

/// A unique temp path that removes itself (and its file/dir) on drop.
pub struct TempPath {
    pub path: PathBuf,
}

impl TempPath {
    /// Unique file path under the system temp dir (not created).
    pub fn file(ext: &str) -> Self {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let path = std::env::temp_dir().join(format!("lumina-test-{pid}-{n}.{ext}"));
        TempPath { path }
    }

    /// Unique directory (created).
    pub fn dir() -> Self {
        let t = Self::file("d");
        std::fs::create_dir_all(&t.path).expect("create temp dir");
        t
    }
}

impl Drop for TempPath {
    fn drop(&mut self) {
        if self.path.is_dir() {
            let _ = std::fs::remove_dir_all(&self.path);
        } else {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Run a randomized property `cases` times with a seeded PRNG, printing
/// the failing seed on panic so failures replay deterministically.
///
/// ```ignore
/// property(64, |rng| {
///     let n = rng.below(100) + 1;
///     assert!(n > 0);
/// });
/// ```
pub fn property(cases: u64, prop: impl Fn(&mut Pcg32)) {
    let base = std::env::var("LUMINA_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xfeed_beefu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {case} (replay with LUMINA_PROP_SEED={seed} and cases=1)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temp_paths_unique() {
        let a = TempPath::file("bin");
        let b = TempPath::file("bin");
        assert_ne!(a.path, b.path);
    }

    #[test]
    fn temp_dir_created_and_cleaned() {
        let p;
        {
            let d = TempPath::dir();
            p = d.path.clone();
            assert!(p.is_dir());
            std::fs::write(p.join("x"), b"hi").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn property_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static RUNS: AtomicU64 = AtomicU64::new(0);
        property(16, |rng| {
            RUNS.fetch_add(1, Ordering::Relaxed);
            let v = rng.f32();
            assert!((0.0..1.0).contains(&v));
        });
        assert_eq!(RUNS.load(Ordering::Relaxed), 16);
    }
}
