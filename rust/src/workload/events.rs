//! Seeded, epoch-synchronous arrival/departure processes.
//!
//! Churn is sampled once per epoch boundary from a dedicated
//! [`Pcg32`] stream — no wall clock, no OS entropy — so the event
//! sequence is a pure function of `(process, seed, epoch history)` and
//! the loadtest's determinism argument reduces to the pool's own.

use crate::util::prng::Pcg32;

/// How many viewers arrive and depart at one epoch boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvents {
    pub arrivals: usize,
    pub departures: usize,
}

/// A seeded arrival/departure process, sampled at epoch boundaries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnProcess {
    /// Memoryless churn: Poisson(`arrivals_per_epoch`) arrivals; each
    /// active viewer independently departs with `departure_prob`.
    Poisson { arrivals_per_epoch: f64, departure_prob: f32 },
    /// A half-sine "day" curve: arrivals ramp from zero to
    /// `peak_arrivals_per_epoch` mid-period and back. Departures stay
    /// memoryless, so the population lags the ramp like real sessions
    /// outliving their arrival hour.
    DiurnalRamp {
        peak_arrivals_per_epoch: f64,
        period_epochs: usize,
        departure_prob: f32,
    },
    /// Background Poisson arrivals plus a one-epoch spike of
    /// `spike_arrivals` extra viewers at `spike_epoch` — the admission
    /// controller's refusal path under load.
    FlashCrowd {
        base_arrivals_per_epoch: f64,
        spike_epoch: usize,
        spike_arrivals: usize,
        departure_prob: f32,
    },
}

impl ChurnProcess {
    /// Sample the events for the boundary entering `epoch`, given
    /// `active` currently-attached viewers. Draws a deterministic
    /// number of variates per call *given the inputs*, so identical
    /// histories replay identical event sequences.
    pub fn events_at(&self, epoch: usize, active: usize, rng: &mut Pcg32) -> ChurnEvents {
        let (lambda, extra, departure_prob) = match *self {
            ChurnProcess::Poisson { arrivals_per_epoch, departure_prob } => {
                (arrivals_per_epoch, 0, departure_prob)
            }
            ChurnProcess::DiurnalRamp {
                peak_arrivals_per_epoch,
                period_epochs,
                departure_prob,
            } => {
                let period = period_epochs.max(1);
                let phase = (epoch % period) as f64 / period as f64;
                let lambda =
                    peak_arrivals_per_epoch * (std::f64::consts::PI * phase).sin().max(0.0);
                (lambda, 0, departure_prob)
            }
            ChurnProcess::FlashCrowd {
                base_arrivals_per_epoch,
                spike_epoch,
                spike_arrivals,
                departure_prob,
            } => {
                let extra = if epoch == spike_epoch { spike_arrivals } else { 0 };
                (base_arrivals_per_epoch, extra, departure_prob)
            }
        };
        let arrivals = poisson(lambda, rng) + extra;
        let departures = (0..active).filter(|_| rng.chance(departure_prob)).count();
        ChurnEvents { arrivals, departures }
    }
}

/// Knuth's product-of-uniforms Poisson sampler, capped at 64 (a runaway
/// lambda must not stall an epoch boundary).
fn poisson(lambda: f64, rng: &mut Pcg32) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    let limit = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.f64();
        if p <= limit || k >= 64 {
            return k;
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_identical_event_sequences() {
        let proc = ChurnProcess::Poisson { arrivals_per_epoch: 1.5, departure_prob: 0.2 };
        let run = || {
            let mut rng = Pcg32::new(9, 0x10AD);
            (0..16).map(|e| proc.events_at(e, 5, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut rng = Pcg32::new(3, 1);
        let n = 4000;
        let total: usize = (0..n).map(|_| poisson(2.0, &mut rng)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 2.0).abs() < 0.15, "poisson mean drifted: {mean}");
    }

    #[test]
    fn flash_crowd_spikes_once() {
        let proc = ChurnProcess::FlashCrowd {
            base_arrivals_per_epoch: 0.0,
            spike_epoch: 3,
            spike_arrivals: 8,
            departure_prob: 0.0,
        };
        let mut rng = Pcg32::new(1, 1);
        for e in 0..6 {
            let ev = proc.events_at(e, 4, &mut rng);
            assert_eq!(ev.arrivals, if e == 3 { 8 } else { 0 });
            assert_eq!(ev.departures, 0);
        }
    }

    #[test]
    fn diurnal_ramp_is_zero_at_period_start_and_peaks_mid_period() {
        let proc = ChurnProcess::DiurnalRamp {
            peak_arrivals_per_epoch: 6.0,
            period_epochs: 8,
            departure_prob: 0.0,
        };
        // Phase 0 has lambda 0: no arrivals regardless of the stream.
        let mut rng = Pcg32::new(2, 2);
        assert_eq!(proc.events_at(0, 3, &mut rng).arrivals, 0);
        assert_eq!(proc.events_at(8, 3, &mut rng).arrivals, 0);
        // Mid-period arrivals average near the peak.
        let mut rng = Pcg32::new(2, 3);
        let total: usize = (0..500).map(|_| proc.events_at(4, 0, &mut rng).arrivals).sum();
        let mean = total as f64 / 500.0;
        assert!((mean - 6.0).abs() < 0.6, "mid-period mean drifted: {mean}");
    }

    #[test]
    fn departures_never_exceed_active() {
        let proc = ChurnProcess::Poisson { arrivals_per_epoch: 0.0, departure_prob: 1.0 };
        let mut rng = Pcg32::new(4, 4);
        let ev = proc.events_at(0, 7, &mut rng);
        assert_eq!(ev.departures, 7);
        let ev = proc.events_at(1, 0, &mut rng);
        assert_eq!(ev.departures, 0);
    }
}
