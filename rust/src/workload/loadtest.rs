//! The loadtest driver: interleave seeded churn, pool epochs, and
//! admission re-planning, and emit a byte-stable SLO report.
//!
//! # Determinism
//!
//! A loadtest is a pure function of `(scenario spec, seed)`:
//!
//! * churn events come from a dedicated [`Pcg32`] stream
//!   ([`LOADTEST_STREAM`]) sampled serially on the coordination thread;
//! * every pool-state input to a churn decision (session count, session
//!   ids, refusal counts) is itself thread-count invariant;
//! * rendered frames are bitwise thread-count and pipeline-depth
//!   invariant (the pool's core guarantee), and every latency is
//!   reported in integer nanoseconds, so the JSON never touches float
//!   formatting of accumulated values.
//!
//! `tests/loadtest.rs` pins the result: same seed, byte-identical JSON
//! at 1/2/4 threads.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::Result;

use super::scenario::{Scenario, ScenarioSpec};
use crate::config::LuminaConfig;
use crate::coordinator::admission::{price_workload, AdmissionController, ADMISSION_HEADROOM};
use crate::coordinator::report::{tier_rank, FrameReport};
use crate::coordinator::steal;
use crate::coordinator::SessionPool;
use crate::util::prng::Pcg32;

/// Dedicated PRNG stream for churn sampling — disjoint from the camera
/// stream by construction, so workload randomness can never perturb
/// trajectories (or vice versa).
pub const LOADTEST_STREAM: u64 = 0x10AD_7E57;

/// Parsed `lumina loadtest` options.
#[derive(Debug, Clone)]
pub struct LoadtestOptions {
    pub scenario: Scenario,
    /// Seeds both the camera base and the churn stream.
    pub seed: u64,
    /// Override the scenario's epoch count.
    pub epochs: Option<usize>,
    /// CI smoke mode: tiny scene, low resolution, few epochs.
    pub smoke: bool,
    /// `--set key=value` config overrides, applied over the scenario's
    /// bound config (e.g. `pool.sort_scope=private`).
    pub overrides: Vec<String>,
}

/// Per-epoch SLO row: population, churn outcome, and nearest-rank
/// latency percentiles over the epoch's frames (integer ns).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EpochSlo {
    pub epoch: usize,
    /// Attached sessions after this boundary's churn.
    pub sessions: usize,
    /// Frames served this epoch (drained frames of departing viewers
    /// count here — they were real served frames).
    pub frames: usize,
    pub arrivals: usize,
    pub departures: usize,
    /// Admissions refused at this boundary.
    pub refused: usize,
    /// Tier demotions observed across this epoch's frames.
    pub demotions: usize,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
}

/// End-of-run per-session row, keyed by the stable
/// [`crate::coordinator::Coordinator::session_id`] (indices shift under
/// churn; ids never do).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionSlo {
    pub id: u64,
    pub frames: usize,
    /// Frames that executed a speculative sort.
    pub sorted: usize,
    pub demotions: usize,
    pub p99_ns: u64,
}

/// The loadtest's result: per-epoch and end-of-run SLOs. All counters
/// are integers, so [`Self::to_json`] is byte-stable across platforms,
/// thread counts, and repeat runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoadtestReport {
    pub scenario: String,
    pub seed: u64,
    pub epoch_frames: usize,
    pub epochs: Vec<EpochSlo>,
    pub sessions: Vec<SessionSlo>,
    pub total_frames: usize,
    pub sorted_frames: usize,
    /// Admissions the controller refused over the whole run.
    pub refusals: usize,
    pub demotions: usize,
    /// Demotions per million served frames (integer arithmetic).
    pub demotion_rate_ppm: u64,
    /// Viewers ever attached (initial + admitted joiners).
    pub admitted: usize,
    /// Viewers retired by departures.
    pub retired: usize,
    /// Arrivals dropped at `max_sessions` before reaching admission.
    pub dropped_at_cap: usize,
    pub peak_sessions: usize,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    /// Idle worker-frames the run's epochs would cost under the
    /// **stealing** scheduler at the nominal
    /// [`steal::MODEL_WORKERS`]-worker pool — the machine-independent
    /// occupancy model ([`steal::idle_worker_frames_stealing`]) summed
    /// over every epoch's per-session frame counts. Deliberately **not**
    /// serialized by [`Self::to_json`]: the SLO bytes must stay
    /// identical across `pool.scheduler`, while these model fields feed
    /// the bench gate's scheduler comparison.
    pub steal_idle_worker_frames: u64,
    /// Same epochs, priced under the **per-session** scheduler's
    /// contiguous-chunk split ([`steal::idle_worker_frames_session`]).
    pub session_idle_worker_frames: u64,
    /// Summed per-epoch critical path (longest single-session frame
    /// chain, [`steal::epoch_critical_path_frames`]) — the floor no
    /// scheduler can beat.
    pub steal_epoch_critical_path_frames: u64,
}

impl LoadtestReport {
    /// Hand-rolled JSON — integers and fixed key order only, so two
    /// identical runs produce identical bytes (the CLI's contract).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"scenario\":\"{}\",\"seed\":{},\"epoch_frames\":{},\"epochs\":[",
            self.scenario, self.seed, self.epoch_frames
        );
        for (i, e) in self.epochs.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"epoch\":{},\"sessions\":{},\"frames\":{},\"arrivals\":{},\
                 \"departures\":{},\"refused\":{},\"demotions\":{},\"p50_ns\":{},\
                 \"p95_ns\":{},\"p99_ns\":{}}}",
                e.epoch,
                e.sessions,
                e.frames,
                e.arrivals,
                e.departures,
                e.refused,
                e.demotions,
                e.p50_ns,
                e.p95_ns,
                e.p99_ns
            );
        }
        s.push_str("],\"sessions\":[");
        for (i, v) in self.sessions.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"id\":{},\"frames\":{},\"sorted\":{},\"demotions\":{},\"p99_ns\":{}}}",
                v.id, v.frames, v.sorted, v.demotions, v.p99_ns
            );
        }
        let _ = write!(
            s,
            "],\"total_frames\":{},\"sorted_frames\":{},\"refusals\":{},\"demotions\":{},\
             \"demotion_rate_ppm\":{},\"admitted\":{},\"retired\":{},\"dropped_at_cap\":{},\
             \"peak_sessions\":{},\"p50_ns\":{},\"p95_ns\":{},\"p99_ns\":{}}}",
            self.total_frames,
            self.sorted_frames,
            self.refusals,
            self.demotions,
            self.demotion_rate_ppm,
            self.admitted,
            self.retired,
            self.dropped_at_cap,
            self.peak_sessions,
            self.p50_ns,
            self.p95_ns,
            self.p99_ns
        );
        s
    }
}

/// Run a named scenario over a base config (the CLI entry point).
pub fn run_loadtest(base: LuminaConfig, opts: &LoadtestOptions) -> Result<LoadtestReport> {
    let mut spec = opts.scenario.spec(base);
    if opts.smoke {
        spec.shrink_for_smoke();
    }
    if let Some(e) = opts.epochs {
        spec.epochs = e.max(1);
    }
    for o in &opts.overrides {
        spec.cfg.apply_override(o)?;
    }
    run_spec(opts.scenario.name(), spec, opts.seed)
}

/// Run a fully-bound spec (tests craft specs directly, e.g. with a
/// deliberately impossible capacity to force refusals).
pub fn run_spec(scenario: &str, mut spec: ScenarioSpec, seed: u64) -> Result<LoadtestReport> {
    let ef = spec.cfg.pool.epoch_frames.max(1);
    spec.cfg.camera.seed = seed;
    spec.cfg.camera.frames = spec.epochs * ef;

    let mut builder =
        SessionPool::builder(spec.cfg.clone()).sessions(spec.initial_sessions.max(1));
    if spec.broadcast {
        builder = builder.stagger(0);
    }
    if !spec.device_mix.is_empty() {
        builder = builder.device_mix(spec.device_mix.clone());
    }
    let mut pool = builder.build()?;

    // Size the admission FPS target from a probe-priced full-tier
    // frame: `capacity_sessions` of them exactly fill the budget.
    // Derived rather than hardcoded, so a scenario keeps its meaning
    // ("holds N viewers") across scene sizes and smoke shrinks.
    let probe = pool.sessions_mut()[0].probe_workload()?;
    let price = price_workload(&probe, pool.sessions()[0].cfg.variant).max(1e-12);
    let mut ctrl_cfg = spec.cfg.clone();
    ctrl_cfg.pool.target_fps =
        (1.0 - ADMISSION_HEADROOM) / (spec.capacity_sessions.max(0.01) * price);
    let ctrl = AdmissionController::from_config(&ctrl_cfg)?;
    // Initial plan with a forced rebuild: probes every session and
    // wipes the probes' stage-state side effects, so served frames
    // start pristine (and every session has a priced workload before
    // the first boundary's churn).
    pool.replan(&ctrl, true)?;

    let mut rng = Pcg32::new(seed, LOADTEST_STREAM);
    let mut by_id: BTreeMap<u64, SessionAgg> = BTreeMap::new();
    let mut all_ns: Vec<u64> = Vec::new();
    let mut epochs_out = Vec::new();
    let mut admitted = spec.initial_sessions.max(1);
    let mut retired = 0usize;
    let mut dropped_at_cap = 0usize;
    let mut peak_sessions = pool.len();
    let mut steal_idle = 0u64;
    let mut session_idle = 0u64;
    let mut critical_path = 0u64;

    for epoch in 0..spec.epochs {
        let mut epoch_ns: Vec<u64> = Vec::new();
        let mut epoch_demotions = 0usize;
        let mut arrivals = 0usize;
        let mut departures = 0usize;
        let refused_before = pool.refusals();

        // Epoch-synchronous churn: departures first (freeing capacity
        // the arrivals may claim), then arrivals through admission.
        if let Some(churn) = spec.churn {
            let ev = churn.events_at(epoch, pool.len(), &mut rng);
            for _ in 0..ev.departures {
                if pool.len() <= 1 {
                    break; // admission prices joiners against a live pool
                }
                let idx = rng.below(pool.len());
                let id = pool.sessions()[idx].session_id;
                for f in pool.retire(idx)? {
                    epoch_ns.push(latency_ns(&f));
                    epoch_demotions += record(&mut by_id, &mut all_ns, id, &f);
                }
                departures += 1;
                retired += 1;
            }
            for _ in 0..ev.arrivals {
                if pool.len() >= spec.max_sessions {
                    dropped_at_cap += 1;
                    continue;
                }
                let mut jc = spec.cfg.clone();
                // Joiners serve to the end of the run, entering on a
                // fresh camera stream (broadcast pools excepted —
                // their spec has no churn).
                jc.camera.frames = (spec.epochs - epoch) * ef;
                jc.camera.seed = seed.wrapping_add(10_000 + admitted as u64);
                if !spec.device_mix.is_empty() {
                    jc.variant = spec.device_mix[admitted % spec.device_mix.len()];
                }
                match pool.admit(jc, &ctrl) {
                    Ok(_) => {
                        admitted += 1;
                        arrivals += 1;
                    }
                    // A refusal is an expected outcome (the pool's
                    // counter records it); anything else is a bug.
                    Err(e) if format!("{e:#}").contains("admission refused") => {}
                    Err(e) => return Err(e),
                }
            }
        }
        peak_sessions = peak_sessions.max(pool.len());

        let frames = pool.run_epoch(ef)?;
        // Occupancy model over this epoch's per-session frame counts:
        // churn makes the counts heterogeneous (joiners serve partial
        // tails, finished sessions serve zero), which is exactly where
        // the contiguous per-session split strands workers and stealing
        // does not. Counts are thread-count invariant, so these sums
        // are as byte-stable as the SLO report itself.
        let counts: Vec<usize> = frames.iter().map(|v| v.len()).collect();
        steal_idle += steal::idle_worker_frames_stealing(&counts, steal::MODEL_WORKERS);
        session_idle += steal::idle_worker_frames_session(&counts, steal::MODEL_WORKERS);
        critical_path += steal::epoch_critical_path_frames(&counts);
        let ids: Vec<u64> = pool.sessions().iter().map(|c| c.session_id).collect();
        for (i, fs) in frames.iter().enumerate() {
            for f in fs {
                epoch_ns.push(latency_ns(f));
                epoch_demotions += record(&mut by_id, &mut all_ns, ids[i], f);
            }
        }

        epochs_out.push(EpochSlo {
            epoch,
            sessions: pool.len(),
            frames: epoch_ns.len(),
            arrivals,
            departures,
            refused: pool.refusals() - refused_before,
            demotions: epoch_demotions,
            p50_ns: percentile_ns(&mut epoch_ns, 50.0),
            p95_ns: percentile_ns(&mut epoch_ns, 95.0),
            p99_ns: percentile_ns(&mut epoch_ns, 99.0),
        });
        if epoch + 1 < spec.epochs {
            pool.replan(&ctrl, false)?;
        }
    }

    let total_frames = all_ns.len();
    let sorted_frames: usize = by_id.values().map(|a| a.sorted).sum();
    let demotions: usize = by_id.values().map(|a| a.demotions).sum();
    let sessions: Vec<SessionSlo> = by_id
        .iter()
        .map(|(&id, a)| {
            let mut ns = a.lat_ns.clone();
            SessionSlo {
                id,
                frames: a.frames,
                sorted: a.sorted,
                demotions: a.demotions,
                p99_ns: percentile_ns(&mut ns, 99.0),
            }
        })
        .collect();
    Ok(LoadtestReport {
        scenario: scenario.to_string(),
        seed,
        epoch_frames: ef,
        epochs: epochs_out,
        sessions,
        total_frames,
        sorted_frames,
        refusals: pool.refusals(),
        demotions,
        demotion_rate_ppm: if total_frames == 0 {
            0
        } else {
            demotions as u64 * 1_000_000 / total_frames as u64
        },
        admitted,
        retired,
        dropped_at_cap,
        peak_sessions,
        p50_ns: percentile_ns(&mut all_ns.clone(), 50.0),
        p95_ns: percentile_ns(&mut all_ns.clone(), 95.0),
        p99_ns: percentile_ns(&mut all_ns, 99.0),
        steal_idle_worker_frames: steal_idle,
        session_idle_worker_frames: session_idle,
        steal_epoch_critical_path_frames: critical_path,
    })
}

/// Per-session accumulator, keyed by stable session id.
#[derive(Debug, Default)]
struct SessionAgg {
    frames: usize,
    sorted: usize,
    demotions: usize,
    last_rank: Option<u8>,
    lat_ns: Vec<u64>,
}

/// Frame latency as integer nanoseconds — the report's unit, chosen so
/// byte comparison never depends on float formatting.
fn latency_ns(f: &FrameReport) -> u64 {
    (f.time_s * 1e9).round() as u64
}

/// Fold one frame into its session's aggregate; returns 1 when the
/// frame is a tier demotion relative to the session's previous frame.
fn record(
    by_id: &mut BTreeMap<u64, SessionAgg>,
    all_ns: &mut Vec<u64>,
    id: u64,
    f: &FrameReport,
) -> usize {
    let ns = latency_ns(f);
    all_ns.push(ns);
    let agg = by_id.entry(id).or_default();
    agg.frames += 1;
    agg.lat_ns.push(ns);
    if f.sorted_this_frame {
        agg.sorted += 1;
    }
    let rank = tier_rank(f.tier);
    let demoted = matches!(agg.last_rank, Some(prev) if rank > prev);
    agg.last_rank = Some(rank);
    if demoted {
        agg.demotions += 1;
        1
    } else {
        0
    }
}

/// Nearest-rank percentile over integer latencies (0 for an empty set).
fn percentile_ns(v: &mut Vec<u64>, p: f64) -> u64 {
    if v.is_empty() {
        return 0;
    }
    v.sort_unstable();
    let rank = ((p.clamp(0.0, 100.0) / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.saturating_sub(1).min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_base() -> LuminaConfig {
        let mut c = LuminaConfig::quick_test();
        c.scene.count = 2500;
        c.camera.width = 32;
        c.camera.height = 32;
        c.pool.epoch_frames = 2;
        c
    }

    fn opts(scenario: Scenario, seed: u64) -> LoadtestOptions {
        LoadtestOptions { scenario, seed, epochs: Some(2), smoke: true, overrides: Vec::new() }
    }

    #[test]
    fn same_seed_is_byte_identical_and_seed_matters() {
        let a = run_loadtest(tiny_base(), &opts(Scenario::PoissonChurn, 11)).unwrap();
        let b = run_loadtest(tiny_base(), &opts(Scenario::PoissonChurn, 11)).unwrap();
        assert_eq!(a.to_json(), b.to_json());
        let c = run_loadtest(tiny_base(), &opts(Scenario::PoissonChurn, 12)).unwrap();
        assert_ne!(a.to_json(), c.to_json(), "seed must steer the run");
    }

    #[test]
    fn overrides_apply_and_bad_overrides_fail() {
        let mut o = opts(Scenario::SpectatorBroadcast, 5);
        o.overrides = vec!["pool.sort_scope=private".to_string()];
        let r = run_loadtest(tiny_base(), &o).unwrap();
        assert!(r.total_frames > 0);
        let mut bad = opts(Scenario::SpectatorBroadcast, 5);
        bad.overrides = vec!["pool.nonsense=1".to_string()];
        assert!(run_loadtest(tiny_base(), &bad).is_err());
    }

    #[test]
    fn impossible_capacity_counts_refusals() {
        let mut spec = Scenario::FlashCrowd.spec(tiny_base());
        spec.shrink_for_smoke();
        spec.epochs = 3;
        // Even one floor-tier session overflows this budget, so every
        // spike admission must be refused.
        spec.capacity_sessions = 0.05;
        let r = run_spec("flash_crowd", spec, 7).unwrap();
        assert!(r.refusals > 0, "saturated pool must refuse: {}", r.to_json());
        let per_epoch: usize = r.epochs.iter().map(|e| e.refused).sum();
        assert_eq!(r.refusals, per_epoch, "epoch rows must account for every refusal");
    }

    #[test]
    fn occupancy_model_fields_populate_but_stay_out_of_the_json() {
        let r = run_loadtest(tiny_base(), &opts(Scenario::FlashCrowd, 7)).unwrap();
        // The model prices every epoch the pool ran.
        assert!(r.steal_epoch_critical_path_frames > 0);
        assert!(
            r.steal_idle_worker_frames <= r.session_idle_worker_frames,
            "stealing can only reduce idle worker-frames: {} vs {}",
            r.steal_idle_worker_frames,
            r.session_idle_worker_frames
        );
        // SLO bytes are scheduler-blind: the model fields must not leak
        // into the JSON contract.
        assert!(!r.to_json().contains("idle_worker"));
        assert!(!r.to_json().contains("critical_path"));
    }

    #[test]
    fn report_json_is_byte_identical_across_schedulers() {
        let mut steal_opts = opts(Scenario::FlashCrowd, 9);
        steal_opts.overrides = vec!["pool.scheduler=stealing".to_string()];
        let a = run_loadtest(tiny_base(), &opts(Scenario::FlashCrowd, 9)).unwrap();
        let b = run_loadtest(tiny_base(), &steal_opts).unwrap();
        assert_eq!(
            a.to_json(),
            b.to_json(),
            "pool.scheduler must not change a single report byte"
        );
        assert_eq!(a.refusals, b.refusals);
        assert_eq!(a.demotions, b.demotions);
    }

    #[test]
    fn report_json_shape_is_consistent() {
        let r = run_loadtest(tiny_base(), &opts(Scenario::TeleportStress, 3)).unwrap();
        let json = r.to_json();
        assert_eq!(json.matches("\"epoch\":").count(), r.epochs.len());
        assert_eq!(json.matches("\"id\":").count(), r.sessions.len());
        assert!(json.starts_with('{') && json.ends_with('}'));
        let frames_by_session: usize = r.sessions.iter().map(|s| s.frames).sum();
        assert_eq!(frames_by_session, r.total_frames);
        assert!(r.p50_ns <= r.p95_ns && r.p95_ns <= r.p99_ns);
        assert!(r.sorted_frames > 0, "teleports must force sorts");
    }
}
