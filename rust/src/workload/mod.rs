//! Population-scale workload harness: seeded viewer churn, adversarial
//! pose families, heterogeneous device mixes, and SLO reporting over a
//! [`crate::coordinator::SessionPool`].
//!
//! The benches serve a handful of viewers on smooth paths and report
//! mean pool FPS; production questions are "what p99 frame latency do
//! *churning* viewers see during a flash crowd, and when does admission
//! start refusing?". This module answers them reproducibly:
//!
//! * [`events`] — arrival/departure processes (Poisson churn, diurnal
//!   ramp, flash crowd). Events are **epoch-synchronous**: they fire
//!   only at epoch boundaries, driven by [`crate::util::prng::Pcg32`]
//!   and never by the wall clock, so a loadtest is a pure function of
//!   `(scenario, seed)`.
//! * [`scenario`] — named scenario presets binding a pose family
//!   (walkthrough, teleport, jittery head-tracking, shared-spectator
//!   broadcast), a device mix, a churn process, and a capacity target.
//! * [`loadtest`] — the driver: builds the pool, derives an admission
//!   controller from a probe-priced capacity target, interleaves
//!   churn / epochs / re-planning, and emits a [`loadtest::LoadtestReport`]
//!   whose JSON is byte-identical across runs and thread counts
//!   (`tests/loadtest.rs` pins 1/2/4 threads).

pub mod events;
pub mod loadtest;
pub mod scenario;

pub use events::{ChurnEvents, ChurnProcess};
pub use loadtest::{run_loadtest, EpochSlo, LoadtestOptions, LoadtestReport, LOADTEST_STREAM};
pub use scenario::{Scenario, ScenarioSpec};
