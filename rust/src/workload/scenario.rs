//! Named loadtest scenarios: a pose family, a churn process, a device
//! mix, and a capacity target, bound into one reproducible preset.

use anyhow::{bail, Result};

use super::events::ChurnProcess;
use crate::camera::trajectory::TrajectoryKind;
use crate::config::{CacheScope, HardwareVariant, LuminaConfig, SortScope};

/// The named scenarios `lumina loadtest --scenario <name>` accepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Memoryless churn over VR viewers with shared cache + clustered
    /// sort scopes — the steady-state serving mix.
    PoissonChurn,
    /// Walkthrough viewers arriving on a half-sine "day" curve.
    DiurnalRamp,
    /// A one-epoch arrival spike against a deliberately tight capacity
    /// target, over a heterogeneous GPU/Lumina/GSCore device mix — the
    /// admission-refusal workload.
    FlashCrowd,
    /// Every viewer replays the identical pose stream (stagger 0):
    /// clustered sorting's best case — one leader sorts, everyone
    /// reuses.
    SpectatorBroadcast,
    /// Dwell-and-jump viewers whose teleports exceed any realistic
    /// `pool.cluster_radius` — clustered sorting's worst case.
    TeleportStress,
}

impl Scenario {
    /// All scenarios, in CLI listing order.
    pub fn all() -> [Scenario; 5] {
        [
            Scenario::PoissonChurn,
            Scenario::DiurnalRamp,
            Scenario::FlashCrowd,
            Scenario::SpectatorBroadcast,
            Scenario::TeleportStress,
        ]
    }

    /// Snake-case CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Scenario::PoissonChurn => "poisson_churn",
            Scenario::DiurnalRamp => "diurnal_ramp",
            Scenario::FlashCrowd => "flash_crowd",
            Scenario::SpectatorBroadcast => "spectator_broadcast",
            Scenario::TeleportStress => "teleport_stress",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Self> {
        for sc in Self::all() {
            if sc.name() == s {
                return Ok(sc);
            }
        }
        let names: Vec<&str> = Self::all().iter().map(|s| s.name()).collect();
        bail!("unknown scenario: {s} (expected one of: {})", names.join(", "))
    }

    /// Bind this scenario's preset over a base config. The preset owns
    /// the pose family, scopes, device mix, churn process, and capacity
    /// target; scene/resolution/epoch knobs stay the caller's
    /// (`--set` overrides apply on top of the returned spec's `cfg`).
    pub fn spec(self, base: LuminaConfig) -> ScenarioSpec {
        let mut cfg = base;
        cfg.variant = HardwareVariant::Lumina;
        cfg.pool.cache_scope = CacheScope::Shared;
        cfg.pool.sort_scope = SortScope::Clustered;
        match self {
            Scenario::PoissonChurn => {
                cfg.camera.trajectory = TrajectoryKind::JitteryHeadTracking;
                ScenarioSpec {
                    cfg,
                    epochs: 8,
                    initial_sessions: 4,
                    max_sessions: 12,
                    churn: Some(ChurnProcess::Poisson {
                        arrivals_per_epoch: 1.0,
                        departure_prob: 0.15,
                    }),
                    broadcast: false,
                    device_mix: Vec::new(),
                    capacity_sessions: 6.0,
                }
            }
            Scenario::DiurnalRamp => {
                cfg.camera.trajectory = TrajectoryKind::Walkthrough;
                ScenarioSpec {
                    cfg,
                    epochs: 10,
                    initial_sessions: 2,
                    max_sessions: 16,
                    churn: Some(ChurnProcess::DiurnalRamp {
                        peak_arrivals_per_epoch: 2.0,
                        period_epochs: 10,
                        departure_prob: 0.2,
                    }),
                    broadcast: false,
                    device_mix: Vec::new(),
                    capacity_sessions: 8.0,
                }
            }
            Scenario::FlashCrowd => {
                cfg.camera.trajectory = TrajectoryKind::VrHeadMotion;
                ScenarioSpec {
                    cfg,
                    epochs: 8,
                    initial_sessions: 3,
                    max_sessions: 24,
                    churn: Some(ChurnProcess::FlashCrowd {
                        base_arrivals_per_epoch: 0.5,
                        spike_epoch: 2,
                        spike_arrivals: 12,
                        departure_prob: 0.1,
                    }),
                    broadcast: false,
                    // GPU and GSCore sessions skip the hubs they lack;
                    // the pool stays heterogeneous per session.
                    device_mix: vec![
                        HardwareVariant::Lumina,
                        HardwareVariant::Gpu,
                        HardwareVariant::GsCore,
                    ],
                    // Tight on purpose — even the floor-tier mix stops
                    // fitting partway through the spike, so the refusal
                    // path is exercised on every run.
                    capacity_sessions: 2.0,
                }
            }
            Scenario::SpectatorBroadcast => {
                cfg.camera.trajectory = TrajectoryKind::VrHeadMotion;
                ScenarioSpec {
                    cfg,
                    // Population large relative to the epoch count so
                    // the handful of leader boundary sorts sits above
                    // the p99 rank: clustered-scope p99 then measures a
                    // *reuse* frame while private-scope p99 (one sort
                    // per sharing window per viewer) measures a sort.
                    epochs: 4,
                    initial_sessions: 24,
                    max_sessions: 24,
                    churn: None,
                    broadcast: true,
                    device_mix: Vec::new(),
                    // Generous: the clustered-vs-private p99 comparison
                    // must measure sorting, not demotion churn.
                    capacity_sessions: 64.0,
                }
            }
            Scenario::TeleportStress => {
                cfg.camera.trajectory = TrajectoryKind::Teleport;
                ScenarioSpec {
                    cfg,
                    epochs: 6,
                    initial_sessions: 6,
                    max_sessions: 6,
                    churn: None,
                    broadcast: false,
                    device_mix: Vec::new(),
                    capacity_sessions: 12.0,
                }
            }
        }
    }
}

/// A fully-bound loadtest scenario — what [`super::loadtest::run_loadtest`]
/// executes.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Base session config (pose family and scopes pre-bound).
    pub cfg: LuminaConfig,
    /// Epochs to serve; each is `cfg.pool.epoch_frames` frames.
    pub epochs: usize,
    /// Viewers attached before the first epoch.
    pub initial_sessions: usize,
    /// Hard cap on attached viewers (arrivals beyond it are dropped
    /// before pricing — they never reach the admission controller).
    pub max_sessions: usize,
    /// Arrival/departure process (`None` = fixed population).
    pub churn: Option<ChurnProcess>,
    /// Stagger-0 convergence: every viewer replays session 0's poses.
    pub broadcast: bool,
    /// Round-robin per-session hardware variants (empty = homogeneous).
    pub device_mix: Vec<HardwareVariant>,
    /// Capacity target in full-tier sessions: the driver sizes the
    /// admission FPS target so this many probe-priced full-tier
    /// sessions exactly fill the budget.
    pub capacity_sessions: f64,
}

impl ScenarioSpec {
    /// Shrink for CI smoke runs: small synthetic scene, low resolution,
    /// few epochs — seconds instead of minutes, same code paths.
    pub fn shrink_for_smoke(&mut self) {
        self.cfg.scene.count = self.cfg.scene.count.min(4000);
        self.cfg.camera.width = self.cfg.camera.width.min(48);
        self.cfg.camera.height = self.cfg.camera.height.min(48);
        self.epochs = self.epochs.min(4);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for sc in Scenario::all() {
            assert_eq!(Scenario::parse(sc.name()).unwrap(), sc);
        }
        let err = Scenario::parse("rush_hour").unwrap_err().to_string();
        assert!(err.contains("flash_crowd"), "error lists valid names: {err}");
    }

    #[test]
    fn flash_crowd_spec_is_heterogeneous_and_tight() {
        let spec = Scenario::FlashCrowd.spec(LuminaConfig::quick_test());
        assert_eq!(spec.device_mix.len(), 3);
        assert!(spec.capacity_sessions < spec.max_sessions as f64);
        assert!(matches!(spec.churn, Some(ChurnProcess::FlashCrowd { .. })));
    }

    #[test]
    fn broadcast_spec_replays_one_path() {
        let spec = Scenario::SpectatorBroadcast.spec(LuminaConfig::quick_test());
        assert!(spec.broadcast);
        assert!(spec.churn.is_none());
    }

    #[test]
    fn smoke_shrink_caps_cost_knobs() {
        let mut spec = Scenario::DiurnalRamp.spec(LuminaConfig::quick_test());
        spec.shrink_for_smoke();
        assert!(spec.cfg.scene.count <= 4000);
        assert!(spec.cfg.camera.width <= 48 && spec.cfg.camera.height <= 48);
        assert!(spec.epochs <= 4);
    }
}
