//! Tiered serving + admission control integration: tier mixes must be
//! bitwise deterministic regardless of worker-thread count (including
//! mid-run promotion/demotion), the controller must hold its pool-FPS
//! target, and a tiered ladder must admit strictly more viewers than an
//! all-full-res pool.

use lumina::config::{HardwareVariant, LuminaConfig, PricingMode, Tier};
use lumina::coordinator::admission::{price_workload, ADMISSION_HEADROOM};
use lumina::coordinator::{AdmissionController, PoolReport, SessionPool};
use lumina::util::par;

/// Tests that flip the global thread count serialize on this lock so
/// they cannot race each other inside one test binary.
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn small_cfg(variant: HardwareVariant) -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 4000;
    c.camera.width = 64;
    c.camera.height = 64;
    c.camera.frames = 6;
    c.pool.epoch_frames = 2;
    c.variant = variant;
    c
}

/// Modeled per-frame cost of one full-tier session under `cfg`.
fn full_frame_cost(cfg: &LuminaConfig) -> f64 {
    let mut pool = SessionPool::builder(cfg.clone()).build().unwrap();
    let demands = pool.probe_demands().unwrap();
    price_workload(&demands[0].workload, cfg.variant)
}

#[test]
fn tiered_pool_bitwise_deterministic_across_thread_counts() {
    let _lock = lock();
    let run = |threads: usize| -> PoolReport {
        par::set_num_threads(threads);
        let mut pool =
            SessionPool::builder(small_cfg(HardwareVariant::Lumina)).sessions(3).build().unwrap();
        pool.set_session_tier(0, Tier::Full).unwrap();
        pool.set_session_tier(1, Tier::Reduced).unwrap();
        pool.set_session_tier(2, Tier::Half).unwrap();
        let r = pool.run().unwrap();
        par::set_num_threads(0);
        r
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(
        serial.sessions, parallel.sessions,
        "thread count changed a tiered pool's reports"
    );
    // Every session rendered its whole trajectory on its own tier.
    for (r, tier) in serial.sessions.iter().zip(["full", "reduced", "half"]) {
        assert_eq!(r.frames.len(), 6);
        assert_eq!(r.tier_sequence(), vec![tier]);
    }
}

#[test]
fn mid_run_tier_swap_sequence_deterministic() {
    let _lock = lock();
    // Demotion (full -> half), lateral (half -> reduced), promotion
    // (reduced -> full) — the sequence a controller would drive.
    let sequence = [Tier::Full, Tier::Half, Tier::Reduced, Tier::Full];
    let run = |threads: usize| {
        par::set_num_threads(threads);
        let mut pool =
            SessionPool::builder(small_cfg(HardwareVariant::Lumina)).sessions(2).build().unwrap();
        let mut frames: Vec<Vec<lumina::coordinator::FrameReport>> = vec![Vec::new(); 2];
        for &tier in sequence.iter() {
            for i in 0..pool.len() {
                pool.set_session_tier(i, tier).unwrap();
            }
            for (i, c) in pool.sessions_mut().iter_mut().enumerate() {
                frames[i].push(c.step().unwrap().report);
            }
        }
        par::set_num_threads(0);
        frames
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(serial, parallel, "thread count changed a tier-swap run");
    let tiers: Vec<&str> = serial[0].iter().map(|f| f.tier).collect();
    assert_eq!(tiers, vec!["full", "half", "reduced", "full"]);
}

#[test]
fn admission_serving_bitwise_deterministic() {
    let _lock = lock();
    let cfg = small_cfg(HardwareVariant::Lumina);
    let cost = full_frame_cost(&cfg);
    // Budget fits ~2.2 full-tier sessions: 3 viewers force a mix, and
    // epoch re-planning exercises mid-run promotion/demotion.
    let target = (1.0 - ADMISSION_HEADROOM) / (2.2 * cost);
    let run = |threads: usize| -> PoolReport {
        par::set_num_threads(threads);
        let ctrl =
            AdmissionController::new(target, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)
                .unwrap();
        let mut pool = SessionPool::builder(cfg.clone()).sessions(3).build().unwrap();
        let r = pool.serve(&ctrl).unwrap();
        par::set_num_threads(0);
        r
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(
        serial.sessions, parallel.sessions,
        "thread count changed an admission-controlled run"
    );
    // Pressure demoted the lowest-priority session away from full.
    let tiers = serial.sessions[2].tier_sequence();
    assert_ne!(tiers, vec!["full"], "expected session 2 demoted, got {tiers:?}");
    // The highest-priority session was demoted last, if at all: it can
    // only have been touched when both lower sessions already dropped.
    assert_eq!(serial.sessions[0].tier_sequence()[0], "full");
}

#[test]
fn pipelined_aggregate_serving_bitwise_deterministic() {
    let _lock = lock();
    // Depth-2 serving under admission control with the O(tiles)
    // aggregate pricing path: the full production configuration must
    // stay bitwise thread-count invariant.
    let mut cfg = small_cfg(HardwareVariant::Lumina);
    cfg.pool.pipeline_depth = 2;
    let cost = full_frame_cost(&cfg);
    let target = (1.0 - ADMISSION_HEADROOM) / (2.2 * cost);
    let run = |threads: usize| -> PoolReport {
        par::set_num_threads(threads);
        let ctrl =
            AdmissionController::new(target, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)
                .unwrap()
                .with_pipeline_depth(2)
                .with_pricing(PricingMode::Aggregate)
                .with_epoch_frames(cfg.pool.epoch_frames);
        let mut pool = SessionPool::builder(cfg.clone()).sessions(3).build().unwrap();
        let r = pool.serve(&ctrl).unwrap();
        par::set_num_threads(0);
        r
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(
        serial.sessions, parallel.sessions,
        "thread count changed a pipelined admission-controlled run"
    );
    assert_eq!(serial.pipeline_depth, 2);
    for r in &serial.sessions {
        assert_eq!(r.frames.len(), 6, "every admitted frame served");
    }
    // Pipelined pricing (max of the overlapped stages) admits a mix at
    // least as good as synchronous sum pricing would.
    let sync_ctrl =
        AdmissionController::new(target, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)
            .unwrap();
    let mut sync_cfg = cfg.clone();
    sync_cfg.pool.pipeline_depth = 1;
    let mut sync_pool = SessionPool::builder(sync_cfg).sessions(3).build().unwrap();
    let sync_report = sync_pool.serve(&sync_ctrl).unwrap();
    let demoted = |r: &PoolReport| {
        r.sessions
            .iter()
            .flat_map(|s| s.frames.iter())
            .filter(|f| f.tier != "full")
            .count()
    };
    assert!(
        demoted(&serial) <= demoted(&sync_report),
        "overlap pricing must not demote more frames than sum pricing \
         ({} vs {})",
        demoted(&serial),
        demoted(&sync_report)
    );
}

#[test]
fn admission_holds_target_and_admits_more_than_full_res() {
    let cfg = small_cfg(HardwareVariant::Gpu);
    let cost = full_frame_cost(&cfg);
    let target = (1.0 - ADMISSION_HEADROOM) / (2.2 * cost);
    let frac = cfg.pool.reduced_fraction;

    let full_only = AdmissionController::new(target, vec![Tier::Full], frac).unwrap();
    let tiered = AdmissionController::new(target, cfg.pool.tiers.clone(), frac).unwrap();

    let max_admitted = |ctrl: &AdmissionController| -> usize {
        let mut admitted = 0;
        for n in 1..=8 {
            let mut pool = SessionPool::builder(cfg.clone()).sessions(n).build().unwrap();
            match pool.probe_demands().and_then(|d| ctrl.plan(&d)) {
                Ok(_) => admitted = n,
                Err(_) => break,
            }
        }
        admitted
    };
    let full_max = max_admitted(&full_only);
    let tiered_max = max_admitted(&tiered);
    assert!(full_max >= 1, "at least one full-res session must fit");
    assert!(tiered_max < 8, "test target too loose to exercise refusal");
    assert!(
        tiered_max > full_max,
        "tiering must admit strictly more sessions ({tiered_max} vs {full_max})"
    );

    // The tiered pool at its maximum admission actually sustains the
    // target (conservative estimates + headroom absorb estimator error).
    let mut pool = SessionPool::builder(cfg.clone()).sessions(tiered_max).build().unwrap();
    let report = pool.serve(&tiered).unwrap();
    assert_eq!(report.total_frames(), tiered_max * 6);
    assert!(
        report.pool_fps() >= target,
        "pool {:.1} fps under target {:.1}",
        report.pool_fps(),
        target
    );

    // One more viewer is refused with a clear error.
    let mut pool = SessionPool::builder(cfg.clone()).sessions(tiered_max + 1).build().unwrap();
    let err = pool.serve(&tiered).unwrap_err();
    assert!(
        format!("{err}").contains("admission refused"),
        "unhelpful refusal: {err}"
    );
    // And the refusal left no probe residue: the un-admitted pool runs
    // byte-identically to one that never attempted serving.
    let refused_run = pool.run().unwrap();
    let fresh_run = SessionPool::builder(cfg.clone())
        .sessions(tiered_max + 1)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(refused_run.sessions, fresh_run.sessions);
}
