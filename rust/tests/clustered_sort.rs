//! Pool-wide pose-clustered S² sorting: the sort-topology seam must be
//! bitwise deterministic (across thread counts, pipeline depths, and
//! mid-run tier swaps), perform strictly fewer speculative sorts than
//! private per-session windows on convergent-pose pools — while every
//! follower still refreshes colors/geometry at its own pose — and keep
//! the kill switch per-session: a fast-rotating member drops to private
//! per-frame sorts without perturbing its cluster.

use lumina::config::{CacheScope, HardwareVariant, LuminaConfig, SortScope, Tier};
use lumina::coordinator::{FrameReport, SessionPool};
use lumina::util::par;

/// Tests that flip the global thread count serialize on this lock so
/// they cannot race each other inside one test binary.
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn clustered_cfg() -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 4000;
    c.camera.width = 64;
    c.camera.height = 64;
    c.camera.frames = 6;
    // Clustered scope shares one sort per epoch; give the private
    // comparison the same amortization window so the redundancy
    // assertion measures cross-session sharing, not window length.
    c.pool.epoch_frames = 2;
    c.s2.sharing_window = 2;
    c.variant = HardwareVariant::S2Gpu;
    c.pool.sort_scope = SortScope::Clustered;
    // Generous radius: the convergent viewers' predicted poses always
    // share one cluster, so the sort count is exactly one per epoch.
    c.pool.cluster_radius = 3.2;
    c
}

fn convergent_pool(cfg: &LuminaConfig, n: usize, stagger: usize) -> SessionPool {
    SessionPool::builder(cfg.clone()).sessions(n).stagger(stagger).build().unwrap()
}

#[test]
fn clustered_pool_bitwise_deterministic_across_threads_depths_and_tier_swaps() {
    let _lock = lock();
    // The acceptance contract: a clustered-sort pool of 3 convergent
    // sessions — on the full Lumina variant, with the shared cache
    // scope engaged too, so the two hubs' epoch machinery interleaves —
    // is bitwise identical at 1/2/4 threads and pipeline depth 1 vs 2,
    // including a mid-run set_tier (demotion to the half-res grid,
    // which leaves the cluster, and promotion back into it).
    let run = |threads: usize, depth: usize| -> Vec<Vec<FrameReport>> {
        par::set_num_threads(threads);
        let mut cfg = clustered_cfg();
        cfg.variant = HardwareVariant::Lumina;
        cfg.pool.cache_scope = CacheScope::Shared;
        cfg.pool.pipeline_depth = depth;
        let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
        let mut frames: Vec<Vec<FrameReport>> = vec![Vec::new(); 3];
        let mut collect = |frames: &mut Vec<Vec<FrameReport>>,
                           epoch: Vec<Vec<FrameReport>>| {
            for (i, f) in epoch.into_iter().enumerate() {
                frames[i].extend(f);
            }
        };
        collect(&mut frames, pool.run_epoch(2).unwrap());
        pool.set_session_tier(1, Tier::Half).unwrap();
        collect(&mut frames, pool.run_epoch(2).unwrap());
        pool.set_session_tier(1, Tier::Full).unwrap();
        collect(&mut frames, pool.run_epoch(2).unwrap());
        par::set_num_threads(0);
        frames
    };
    let reference = run(1, 1);
    for (threads, depth) in [(2usize, 1usize), (4, 1), (1, 2), (2, 2), (4, 2)] {
        let got = run(threads, depth);
        assert_eq!(
            reference, got,
            "clustered-sort pool diverged at {threads} threads, depth {depth}"
        );
    }
    for s in &reference {
        assert_eq!(s.len(), 6, "every session serves its whole trajectory");
    }
    let tiers: Vec<&str> = reference[1].iter().map(|f| f.tier).collect();
    assert_eq!(tiers, vec!["full", "full", "half", "half", "full", "full"]);
    // The sharing is real: followers rendered frames without sorting.
    let reused = reference
        .iter()
        .flatten()
        .filter(|f| !f.sorted_this_frame)
        .count();
    assert!(reused > 0, "clustered pool produced no sort reuse");
}

#[test]
fn clustered_scope_performs_strictly_fewer_sorts_on_convergent_pool() {
    let cfg = clustered_cfg();
    let mut private_cfg = cfg.clone();
    private_cfg.pool.sort_scope = SortScope::Private;
    let stagger = cfg.pool.epoch_frames;

    let clustered = convergent_pool(&cfg, 3, stagger).run().unwrap();
    let private = convergent_pool(&private_cfg, 3, stagger).run().unwrap();

    // Private: every session sorts once per window (6 frames / window 2
    // = 3 sorts x 3 sessions). Clustered: one leader sort per epoch
    // (6 frames / epoch 2 = 3 sorts, pool-wide).
    assert_eq!(private.sorted_frames(), 9, "private windows sort per session");
    assert_eq!(clustered.sorted_frames(), 3, "one cluster sort per epoch");
    assert!(
        clustered.sorted_frames() < private.sorted_frames(),
        "clustered scope must perform strictly fewer speculative sorts"
    );

    // Followers (sessions 1, 2) never sorted — the leader did.
    for i in 1..3 {
        assert!(
            clustered.sessions[i].frames.iter().all(|f| !f.sorted_this_frame),
            "session {i} is a follower and must not sort"
        );
    }
    // ...but every frame still pays per-pose refresh work: the
    // frontend is never free, and per-session outputs differ because
    // each viewer refreshed colors/geometry at its own staggered pose.
    for s in &clustered.sessions {
        for f in &s.frames {
            assert!(f.frontend_s > 0.0, "refresh must cost frontend time every frame");
        }
    }
    assert_ne!(
        clustered.sessions[1].frames, clustered.sessions[2].frames,
        "followers render their own staggered poses, not the leader's"
    );
}

#[test]
fn kill_switch_drops_member_to_private_sorts_without_perturbing_cluster() {
    let _lock = lock();
    let baseline = {
        let mut pool = convergent_pool(&clustered_cfg(), 3, 2);
        pool.run().unwrap()
    };
    let run_killed = |threads: usize| {
        par::set_num_threads(threads);
        let mut pool = convergent_pool(&clustered_cfg(), 3, 2);
        // Session 2 trips the kill switch on every frame that has pose
        // history (negative threshold = any rotation is too fast).
        pool.sessions_mut()[2].set_s2_max_rotation(-1.0);
        let r = pool.run().unwrap();
        par::set_num_threads(0);
        r
    };
    let killed = run_killed(1);

    // The fast-rotating member sorted privately: frame 0 follows the
    // cluster (no pose history yet), every later frame sorts.
    let sorted: Vec<bool> =
        killed.sessions[2].frames.iter().map(|f| f.sorted_this_frame).collect();
    assert_eq!(sorted, vec![false, true, true, true, true, true]);

    // The rest of the cluster is bitwise unperturbed: same leader, same
    // shared sorts, same frames.
    assert_eq!(baseline.sessions[0].frames, killed.sessions[0].frames);
    assert_eq!(baseline.sessions[1].frames, killed.sessions[1].frames);

    // And the kill-switch run itself stays thread-count deterministic.
    let killed4 = run_killed(4);
    assert_eq!(killed.sessions, killed4.sessions);
}

#[test]
fn opt_out_session_keeps_private_windows_while_cluster_shares() {
    let mut pool = convergent_pool(&clustered_cfg(), 3, 2);
    pool.set_sort_opt_out(1, true).unwrap();
    assert!(!pool.sessions()[1].sorts_clustered());
    assert!(pool.sessions()[0].sorts_clustered());
    let report = pool.run().unwrap();

    // Session 1 runs its own private windows (6 frames / window 2 = 3
    // sorts); the remaining two-member cluster still shares one sort
    // per epoch through its leader, session 0.
    let sorts_per_session: Vec<usize> = report
        .sessions
        .iter()
        .map(|r| r.frames.iter().filter(|f| f.sorted_this_frame).count())
        .collect();
    assert_eq!(sorts_per_session, vec![3, 3, 0]);
    assert_eq!(report.sorted_frames(), 6);
}
