//! End-to-end integration: full coordinator runs across variants,
//! checking the paper's headline orderings hold on a small workload.

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::Coordinator;

fn cfg(variant: HardwareVariant) -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 8000;
    c.camera.width = 128;
    c.camera.height = 128;
    c.camera.frames = 12;
    c.s2.expanded_margin = 2; // keep raster inflation low at this scale
    c.variant = variant;
    c
}

#[test]
fn variant_ordering_matches_paper() {
    // Fig. 22 shape: Lumina > S2-Acc > NRU+GPU > S2-GPU > GPU > RC-GPU,
    // checked as a set of pairwise orderings on mean frame time.
    let mut times = std::collections::HashMap::new();
    for v in HardwareVariant::evaluation_set() {
        let mut coord = Coordinator::new(cfg(v)).unwrap();
        let r = coord.run().unwrap();
        times.insert(v, r.mean_time_s());
    }
    let t = |v: HardwareVariant| times[&v];
    assert!(t(HardwareVariant::Lumina) < t(HardwareVariant::Gpu));
    assert!(t(HardwareVariant::S2Acc) < t(HardwareVariant::NruGpu));
    assert!(t(HardwareVariant::NruGpu) < t(HardwareVariant::Gpu));
    // S^2-GPU's 1.2x (Fig. 22) depends on paper workload proportions
    // (sorting ~23% of the frame); at this unit-test scale the expanded
    // viewport's extra raster work can cancel the savings (exactly the
    // Fig. 23b trade-off), so require "not meaningfully worse".
    assert!(t(HardwareVariant::S2Gpu) < t(HardwareVariant::Gpu) * 1.15);
    assert!(t(HardwareVariant::RcGpu) > t(HardwareVariant::Gpu), "RC-GPU must slow down");
    assert!(t(HardwareVariant::Lumina) <= t(HardwareVariant::S2Acc) * 1.05);
}

#[test]
fn energy_ordering_matches_paper() {
    let mut energies = std::collections::HashMap::new();
    for v in [
        HardwareVariant::Gpu,
        HardwareVariant::RcGpu,
        HardwareVariant::NruGpu,
        HardwareVariant::Lumina,
    ] {
        let mut coord = Coordinator::new(cfg(v)).unwrap();
        let r = coord.run().unwrap();
        energies.insert(v, r.mean_energy_j());
    }
    assert!(energies[&HardwareVariant::Lumina] < energies[&HardwareVariant::NruGpu]);
    assert!(energies[&HardwareVariant::NruGpu] < energies[&HardwareVariant::Gpu]);
    assert!(energies[&HardwareVariant::RcGpu] > energies[&HardwareVariant::Gpu]);
}

#[test]
fn quality_stays_high_for_lumina() {
    let mut coord = Coordinator::new(cfg(HardwareVariant::Lumina)).unwrap();
    let mut psnrs = Vec::new();
    for _ in 0..6 {
        let f = coord.step_with_quality().unwrap();
        psnrs.push(f.report.psnr_vs_ref.unwrap());
    }
    let mean = psnrs.iter().sum::<f64>() / psnrs.len() as f64;
    // The raw synthetic scene keeps its oversized-Gaussian tail (the
    // Fig. 13 failure mode RC fine-tuning exists to fix), so the bound
    // here is looser than the fine-tuned fig20/fig21 harness runs.
    assert!(mean > 22.0, "Lumina mean PSNR {mean} dB vs exact pipeline");
}

#[test]
fn cache_warms_across_frames() {
    let mut coord = Coordinator::new(cfg(HardwareVariant::Lumina)).unwrap();
    let first = coord.step().unwrap();
    let mut later_hit = 0.0;
    for _ in 0..4 {
        later_hit = coord.step().unwrap().report.cache.hit_rate();
    }
    assert!(
        later_hit >= first.report.cache.hit_rate() * 0.8,
        "cache should stay warm: first {} later {}",
        first.report.cache.hit_rate(),
        later_hit
    );
    assert!(later_hit > 0.3, "steady-state hit rate {later_hit}");
}

#[test]
fn rapid_rotation_trajectory_survives() {
    let mut c = cfg(HardwareVariant::Lumina);
    c.camera.trajectory = lumina::camera::trajectory::TrajectoryKind::RapidRotation;
    let mut coord = Coordinator::new(c).unwrap();
    let r = coord.run().unwrap();
    assert_eq!(r.frames.len(), 12);
    assert!(r.fps() > 0.0);
}
