//! Workload-harness integration: loadtest reports must be byte-stable
//! across worker-thread counts, `SessionPool::retire` must drain
//! pipelined slots cleanly under the shared cache/sort scopes, and
//! teleport pose streams must break sort-cluster membership.

use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::SessionPool;
use lumina::util::par;
use lumina::workload::{run_loadtest, LoadtestOptions, Scenario};

/// Tests that flip the global thread count serialize on this lock so
/// they cannot race each other inside one test binary.
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_base() -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 2500;
    c.camera.width = 32;
    c.camera.height = 32;
    c.pool.epoch_frames = 2;
    c
}

#[test]
fn loadtest_reports_byte_identical_across_thread_counts() {
    let _lock = lock();
    // The acceptance contract: the same (scenario, seed) must serialize
    // to the same bytes whether the pool renders on 1, 2, or 4 worker
    // threads — churn, admission refusals, demotions, and every
    // latency percentile included.
    for scenario in [Scenario::FlashCrowd, Scenario::PoissonChurn] {
        let opts = LoadtestOptions {
            scenario,
            seed: 7,
            epochs: Some(3),
            smoke: true,
            overrides: Vec::new(),
        };
        let run = |threads: usize| {
            par::set_num_threads(threads);
            let r = run_loadtest(tiny_base(), &opts).unwrap();
            par::set_num_threads(0);
            r.to_json()
        };
        let reference = run(1);
        for threads in [2usize, 4] {
            assert_eq!(
                reference,
                run(threads),
                "{} loadtest diverged at {threads} threads",
                scenario.name()
            );
        }
    }
}

#[test]
fn retire_drains_pipelined_slots_under_shared_scopes() {
    let _lock = lock();
    // A viewer departs mid-epoch with a frame in flight, while both
    // pool-wide hubs (shared cache, clustered sort) hold state for it.
    // retire() must hand back the drained frame, detach the session
    // from both hubs, and leave the remaining pool serving
    // deterministically.
    let mut cfg = tiny_base();
    cfg.variant = HardwareVariant::Lumina;
    cfg.camera.frames = 6;
    cfg.apply_override("pool.cache_scope=shared").unwrap();
    cfg.apply_override("pool.sort_scope=clustered").unwrap();
    cfg.apply_override("pool.pipeline_depth=2").unwrap();
    let run = |threads: usize| {
        par::set_num_threads(threads);
        let mut pool =
            SessionPool::builder(cfg.clone()).sessions(3).stagger(2).build().unwrap();
        // One epoch first, so the shared cache has merged deltas and the
        // sort hub has published clusters that include the departer.
        let warm = pool.run_epoch(2).unwrap();
        assert_eq!(warm.len(), 3);
        // Mid-epoch: prime the departing session's pipeline so a frame
        // is genuinely in flight when retire lands.
        assert!(
            pool.sessions_mut()[1].step_pipelined().unwrap().is_none(),
            "priming dispatch completes no frame"
        );
        assert_eq!(pool.sessions_mut()[1].in_flight(), 1);
        let drained = pool.retire(1).unwrap();
        assert_eq!(drained.len(), 1, "the in-flight frame drains on retire");
        assert_eq!(pool.len(), 2);
        let ids: Vec<u64> = pool.sessions().iter().map(|c| c.session_id).collect();
        assert_eq!(ids, vec![0, 2], "indices shift, stable ids do not");
        // The survivors keep serving through the re-synced hubs.
        let after = pool.run_epoch(2).unwrap();
        assert_eq!(after.len(), 2);
        par::set_num_threads(0);
        (drained, warm, after)
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial, parallel, "retire sequence is thread-count dependent");
}

#[test]
fn teleport_poses_break_sort_clusters() {
    // Staggered convergent viewers at the default cluster radius
    // (0.35 rad): a smooth VR path keeps all three in one cluster (one
    // leader sort per epoch), while the teleport path's >= 1 rad jumps
    // sweep through the stagger windows and split the cluster at the
    // boundaries that straddle a jump — so the pool-wide speculative
    // sort count must strictly rise.
    let mut cfg = tiny_base();
    cfg.variant = HardwareVariant::S2Gpu;
    cfg.camera.frames = 12; // global path 16 frames: the jump at frame 12 lands in-window
    cfg.s2.sharing_window = 2;
    cfg.apply_override("pool.sort_scope=clustered").unwrap();
    assert_eq!(cfg.pool.cluster_radius, 0.35, "test assumes the default radius");
    let sorts = |kind: TrajectoryKind| {
        let mut c = cfg.clone();
        c.camera.trajectory = kind;
        let report = SessionPool::builder(c)
            .sessions(3)
            .stagger(2)
            .build()
            .unwrap()
            .run()
            .unwrap();
        report.sorted_frames()
    };
    let smooth = sorts(TrajectoryKind::VrHeadMotion);
    let teleport = sorts(TrajectoryKind::Teleport);
    assert!(
        teleport > smooth,
        "teleport jumps must break cluster membership: {teleport} sorts vs {smooth} on the smooth path"
    );
}
