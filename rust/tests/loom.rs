//! Loom models of the crate's unsafe parallel publication patterns.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (with the `loom` dev
//! dependency added for the run — it is not part of the offline build's
//! vendored set), so tier-1 `cargo test -q` sees an empty crate here.
//! The CI `analysis` job runs these.
//!
//! What loom buys over the dynamic 1/2/4-thread tests: it *exhaustively
//! enumerates* the interleavings (and, via its C11 memory model, the
//! weak-memory reorderings) of each modeled pattern, rather than
//! sampling whatever the host scheduler happens to produce. The models
//! mirror the crate's unsafe publication idioms — the `par_map`
//! atomic-claim raw-slot write, the `par_chunks_mut` precomputed
//! disjoint ranges, the sort scatter's exclusive prefix-sum segments,
//! and the stealing scheduler's task-claim round
//! (`coordinator::steal`). They cannot model the real functions directly
//! (loom requires `'static` spawns and its own sync types, while the
//! real code uses `std::thread::scope` over borrowed buffers), so each
//! reproduces the claim/write protocol verbatim at model scale; the
//! protocol, not the buffer plumbing, is what carries the soundness
//! argument. Scales stay tiny (2 workers, <= 4 slots): loom's state
//! space is exponential in events per execution.
#![cfg(loom)]

use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::thread;
use std::sync::Arc;

/// Per-slot cells shared across model workers, standing in for the
/// `SendPtr`-wrapped base pointer of `util::par` / the sort scatter.
struct Slots(Vec<UnsafeCell<usize>>);

// SAFETY: model workers only touch pairwise-disjoint slot indices
// (atomic claim counters or precomputed segment bounds — the same
// discipline the real SendPtr users follow), and loom's UnsafeCell
// instruments every access, so any violation of that claim fails the
// model rather than going unnoticed.
unsafe impl Send for Slots {}
// SAFETY: as above — shared references only enable disjoint, loom-
// instrumented accesses.
unsafe impl Sync for Slots {}

impl Slots {
    fn new(n: usize) -> Arc<Self> {
        Arc::new(Slots((0..n).map(|_| UnsafeCell::new(0)).collect()))
    }

    fn write(&self, i: usize, v: usize) {
        self.0[i].with_mut(|p| {
            // SAFETY: `i` is exclusively claimed by the calling worker
            // (loom verifies: concurrent conflicting access panics).
            unsafe { *p = v };
        });
    }

    fn read(&self, i: usize) -> usize {
        self.0[i].with(|p| {
            // SAFETY: called only after every writer has been joined.
            unsafe { *p }
        })
    }
}

/// `par_map`'s dynamic-claim path: workers `fetch_add` a shared counter
/// to claim item indices and write results into raw slots. Loom proves
/// the claimed-index writes are race-free and all published to the
/// joining thread.
#[test]
fn par_map_dynamic_claim_publishes_all_slots() {
    const N: usize = 4;
    const WORKERS: usize = 2;
    loom::model(|| {
        let slots = Slots::new(N);
        let next = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..WORKERS)
            .map(|_| {
                let slots = Arc::clone(&slots);
                let next = Arc::clone(&next);
                thread::spawn(move || loop {
                    // Relaxed suffices exactly as in par_map: the claim
                    // only needs uniqueness, and publication to the
                    // parent happens-before via join.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= N {
                        break;
                    }
                    slots.write(i, i + 1);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for i in 0..N {
            assert_eq!(slots.read(i), i + 1);
        }
    });
}

/// `par_chunks_mut`: chunk ranges are precomputed to tile the buffer
/// disjointly, and workers claim whole chunks via `fetch_add`.
#[test]
fn par_chunks_mut_claimed_ranges_are_disjoint_and_complete() {
    const LEN: usize = 4;
    const CHUNK: usize = 2;
    loom::model(|| {
        let slots = Slots::new(LEN);
        let next = Arc::new(AtomicUsize::new(0));
        let chunks: Arc<Vec<(usize, usize)>> = Arc::new(
            (0..LEN.div_ceil(CHUNK))
                .map(|i| (i * CHUNK, ((i + 1) * CHUNK).min(LEN)))
                .collect(),
        );
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let slots = Arc::clone(&slots);
                let next = Arc::clone(&next);
                let chunks = Arc::clone(&chunks);
                thread::spawn(move || loop {
                    let ci = next.fetch_add(1, Ordering::Relaxed);
                    if ci >= chunks.len() {
                        break;
                    }
                    let (lo, hi) = chunks[ci];
                    for i in lo..hi {
                        slots.write(i, 10 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for i in 0..LEN {
            assert_eq!(slots.read(i), 10 + i);
        }
    });
}

/// The stealing scheduler's round protocol
/// (`coordinator::steal::run_round` over `par::TaskClaimer`): a fixed
/// task list is claimed via `fetch_add`, each claimed task writes one
/// pre-allocated output slot, and the coordination thread reads every
/// slot only after joining the workers. Two sessions contribute
/// heterogeneous rounds (session 0: raster + frontend, session 1: a
/// whole depth-1 step), standing in for the per-field projections —
/// tasks 0 and 1 write *different* cells of session 0's pair, modeling
/// the disjoint `addr_of_mut!` field borrows, while task 2 owns session
/// 1's cell outright. Loom proves no interleaving lets two workers
/// touch the same cell, and that every slot's write is visible to the
/// post-join commit.
#[test]
fn steal_round_claims_tasks_once_and_publishes_all_slots() {
    // Task 0: session 0 raster; task 1: session 0 frontend; task 2:
    // session 1 step. Session cells: [s0.raster, s0.frontend, s1].
    const TASKS: usize = 3;
    const WORKERS: usize = 2;
    loom::model(|| {
        let sessions = Slots::new(TASKS);
        let outs = Slots::new(TASKS);
        let next = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..WORKERS)
            .map(|_| {
                let sessions = Arc::clone(&sessions);
                let outs = Arc::clone(&outs);
                let next = Arc::clone(&next);
                thread::spawn(move || loop {
                    // TaskClaimer::next — Relaxed fetch_add: the claim
                    // only needs RMW uniqueness; publication of the
                    // slot writes happens-before via join.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= TASKS {
                        break;
                    }
                    // "Run" the task: mutate its session cell (the
                    // field the real task projects), then publish into
                    // its claimed output slot.
                    sessions.write(i, 7 + i);
                    outs.write(i, 70 + i);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Post-join commit in task-ID order: every stage output and
        // every session mutation is visible, exactly once.
        for i in 0..TASKS {
            assert_eq!(sessions.read(i), 7 + i);
            assert_eq!(outs.read(i), 70 + i);
        }
    });
}

/// The sort scatter's two-pass prefix-sum protocol: per-(chunk, tile)
/// exclusive start cursors carve the flat entry buffer into disjoint
/// segments, one worker per chunk writes its segments unsynchronized,
/// and the merged layout equals serial insertion order. 2 chunks x 2
/// tiles, one entry per (chunk, tile) pair.
#[test]
fn scatter_prefix_sum_segments_are_disjoint_and_ordered() {
    const N_CHUNKS: usize = 2;
    const N_TILES: usize = 2;
    loom::model(|| {
        // counts[ci][t] = 1; tile bases [0, 2]; starts[ci][t] = base +
        // earlier chunks' counts — exactly pass 2a + the exclusive scan
        // of `bin_with_chunk`.
        let starts: Arc<Vec<Vec<usize>>> = Arc::new(vec![vec![0, 2], vec![1, 3]]);
        let entries = Slots::new(N_CHUNKS * N_TILES);
        let handles: Vec<_> = (0..N_CHUNKS)
            .map(|ci| {
                let entries = Arc::clone(&entries);
                let starts = Arc::clone(&starts);
                thread::spawn(move || {
                    let mut cur = starts[ci].clone();
                    for t in 0..N_TILES {
                        // The model's "splat id": which chunk wrote it.
                        entries.write(cur[t], 100 * ci + t);
                        cur[t] += 1;
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Serial insertion order per tile: chunk 0's entry, then chunk
        // 1's — tile 0 at [0, 2), tile 1 at [2, 4).
        assert_eq!(entries.read(0), 0, "tile 0, chunk 0");
        assert_eq!(entries.read(1), 100, "tile 0, chunk 1");
        assert_eq!(entries.read(2), 1, "tile 1, chunk 0");
        assert_eq!(entries.read(3), 101, "tile 1, chunk 1");
    });
}
