//! Property-based invariants over the pipeline, cache, and scheduler
//! (seeded random cases via `util::testing::property`).

use lumina::camera::{Intrinsics, Pose};
use lumina::constants::TILE;
use lumina::lumina::rc::RadianceCache;
use lumina::math::Vec3;
use lumina::pipeline::project::project;
use lumina::pipeline::raster::{composite_pixel, rasterize, RasterConfig};
use lumina::pipeline::sort::{
    bin_and_sort, bin_and_sort_rect, f32_sort_key, order_change_fraction,
};
use lumina::scene::synth::{synth_scene, SceneClass};
use lumina::util::prng::Pcg32;
use lumina::util::testing::property;

#[test]
fn prop_sort_key_order_preserving() {
    property(256, |rng| {
        let a = f32::from_bits(rng.next_u32() & 0x7fff_ffff); // positive
        let b = f32::from_bits(rng.next_u32() & 0x7fff_ffff);
        if a.is_nan() || b.is_nan() {
            return;
        }
        assert_eq!(a < b, f32_sort_key(a) < f32_sort_key(b), "{a} vs {b}");
    });
}

#[test]
fn prop_transmittance_in_unit_interval() {
    property(24, |rng| {
        let scene = synth_scene(SceneClass::SyntheticSmall, rng.next_u64(), 800);
        let eye = Vec3::new(
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-0.5, 0.5),
            rng.range_f32(-5.0, -3.0),
        );
        let pose = Pose::look_at(eye, Vec3::ZERO);
        let intr = Intrinsics::with_fov(64, 64, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, TILE, 0.0);
        for _ in 0..8 {
            let x = rng.below(64);
            let y = rng.below(64);
            let tile = (y / TILE) * bins.tiles_x + x / TILE;
            let (c, t, it, sig, _) = composite_pixel(
                &p,
                bins.list(tile),
                x as f32 + 0.5,
                y as f32 + 0.5,
                0,
            );
            assert!((0.0..=1.0).contains(&t), "transmittance {t}");
            assert!(sig <= it);
            for ch in c {
                assert!(ch.is_finite() && ch >= 0.0);
            }
        }
    });
}

#[test]
fn prop_compositing_weights_bounded() {
    // Sum of blend weights = 1 - final transmittance <= 1; so any color
    // channel is bounded by the max per-Gaussian color.
    property(12, |rng| {
        let scene = synth_scene(SceneClass::SyntheticSmall, rng.next_u64(), 600);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(48, 48, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let max_color = p
            .colors
            .iter()
            .flat_map(|c| c.iter().copied())
            .fold(0.0f32, f32::max);
        let bins = bin_and_sort(&p, &intr, TILE, 0.0);
        let out = rasterize(&p, &bins, 48, 48, &RasterConfig::default());
        for px in &out.image.data {
            for ch in px {
                assert!(*ch <= max_color + 1e-4, "channel {ch} > max color {max_color}");
            }
        }
    });
}

#[test]
fn prop_cache_lookup_after_insert_hits() {
    property(128, |rng| {
        let k = 1 + rng.below(5);
        let mut cache = RadianceCache::paper_default(k);
        let ids: Vec<u32> = (0..k).map(|_| rng.next_u32() >> 8).collect();
        let val = [rng.f32(), rng.f32(), rng.f32()];
        cache.insert(&ids, val);
        assert_eq!(cache.lookup(&ids), Some(val));
    });
}

#[test]
fn prop_cache_never_returns_foreign_value() {
    // Whatever is returned was inserted under the same (index, tag) —
    // i.e. the same masked ID fields.
    property(64, |rng| {
        let mut cache = RadianceCache::paper_default(2);
        let mut inserted: Vec<(Vec<u32>, [f32; 3])> = Vec::new();
        for _ in 0..200 {
            let ids: Vec<u32> = (0..2).map(|_| rng.next_u32() & 0xffff).collect();
            let val = [rng.f32(), 0.0, 0.0];
            cache.insert(&ids, val);
            inserted.push((ids, val));
        }
        for (ids, _) in &inserted {
            if let Some(got) = cache.lookup(ids) {
                // The value must be one inserted under IDs that agree on
                // the bits the cache can see (bits 3..19 of each ID).
                let visible = |v: &[u32]| -> Vec<u32> {
                    v.iter().map(|x| (x >> 3) & 0xffff).collect()
                };
                let mine = visible(ids);
                assert!(
                    inserted
                        .iter()
                        .any(|(oids, oval)| visible(oids) == mine && *oval == got),
                    "foreign value {got:?} for ids {ids:?}"
                );
            }
        }
    });
}

#[test]
fn prop_order_change_fraction_bounds() {
    property(128, |rng| {
        let n = 2 + rng.below(50);
        let mut a: Vec<u32> = (0..n as u32).collect();
        let mut b = a.clone();
        rng.shuffle(&mut a);
        rng.shuffle(&mut b);
        let f = order_change_fraction(&a, &b);
        assert!((0.0..=1.0).contains(&f));
        assert_eq!(order_change_fraction(&a, &a), 0.0);
    });
}

#[test]
fn prop_projection_culls_consistently() {
    // A Gaussian retained with margin 0 must also be retained with any
    // larger margin (monotonicity of the expanded viewport).
    property(16, |rng| {
        let scene = synth_scene(SceneClass::SyntheticSmall, rng.next_u64(), 500);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(64, 64, 0.9);
        let tight = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let margin = rng.range_f32(1.0, 64.0);
        let loose = project(&scene, &pose, &intr, 0.2, 100.0, margin);
        let loose_ids: std::collections::HashSet<u32> = loose.ids.iter().copied().collect();
        for id in &tight.ids {
            assert!(loose_ids.contains(id), "margin {margin} dropped id {id}");
        }
    });
}

#[test]
fn prop_exact_binning_matches_rect_bitwise() {
    // Exact circle-vs-tile binning may only drop (splat, tile) pairs
    // whose significance disc misses every pixel center of the tile, so
    // across tile sizes, margins (0 and > 0), and non-square images the
    // rasterized frame is bitwise identical to rect binning while the
    // per-tile entry counts never grow.
    property(8, |rng| {
        let scene = synth_scene(SceneClass::SyntheticSmall, rng.next_u64(), 700);
        let eye = Vec3::new(
            rng.range_f32(-0.8, 0.8),
            rng.range_f32(-0.4, 0.4),
            rng.range_f32(-4.5, -3.0),
        );
        let pose = Pose::look_at(eye, Vec3::ZERO);
        let (w, h) = if rng.below(2) == 0 { (80, 48) } else { (48, 80) };
        let intr = Intrinsics::with_fov(w, h, 0.9);
        let margin = if rng.below(2) == 0 { 0.0 } else { rng.range_f32(1.0, 24.0) };
        let p = project(&scene, &pose, &intr, 0.2, 100.0, margin);
        let tile_size = [8, TILE, 32][rng.below(3)];
        let exact = bin_and_sort(&p, &intr, tile_size, margin);
        let rect = bin_and_sort_rect(&p, &intr, tile_size, margin);
        assert_eq!(exact.tile_count(), rect.tile_count());
        assert!(exact.total_entries() <= rect.total_entries());
        // Exact mode skips never-significant splats before the rect
        // walk, so its candidate count can only be smaller.
        assert!(exact.rect_candidates() <= rect.rect_candidates());
        for tile in 0..exact.tile_count() {
            assert!(
                exact.list(tile).len() <= rect.list(tile).len(),
                "tile {tile} grew under exact binning (margin {margin})"
            );
        }
        let cfg = RasterConfig::default();
        let out_exact = rasterize(&p, &exact, w, h, &cfg);
        let out_rect = rasterize(&p, &rect, w, h, &cfg);
        assert_eq!(
            out_exact.image.data, out_rect.image.data,
            "exact binning changed the image (tile {tile_size}, margin {margin})"
        );
    });
}

#[test]
fn prop_prng_streams_independent() {
    property(32, |rng| {
        let seed = rng.next_u64();
        let mut a = Pcg32::new(seed, 1);
        let mut b = Pcg32::new(seed, 2);
        let matches = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(matches < 4);
    });
}
