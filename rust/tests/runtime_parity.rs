//! Integration: the three layers must agree bit-closely.
//!
//! The native Rust rasterizer (L3), the AOT-compiled Pallas kernel (L1,
//! via PJRT), and the SH evaluators are checked against each other on
//! real projected scenes. Skips with a notice if `artifacts/` has not
//! been built (run `make artifacts`).

use lumina::camera::{Intrinsics, Pose};
use lumina::constants::{SH_COEFFS, TILE};
use lumina::math::Vec3;
use lumina::pipeline::project::project;
use lumina::pipeline::raster::composite_pixel;
use lumina::pipeline::sort::bin_and_sort;
use lumina::runtime::ArtifactRuntime;
use lumina::scene::sh::eval_color;
use lumina::scene::synth::test_scene;

fn runtime() -> Option<ArtifactRuntime> {
    if cfg!(not(feature = "xla-runtime")) {
        eprintln!("SKIP: built without the `xla-runtime` feature");
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.toml").exists() {
        eprintln!("SKIP: artifacts/ not built; run `make artifacts`");
        return None;
    }
    Some(ArtifactRuntime::load("artifacts").expect("loading artifacts"))
}

#[test]
fn raster_tile_matches_native_compositor() {
    let Some(rt) = runtime() else { return };
    let scene = test_scene(404, 4000);
    let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
    let intr = Intrinsics::with_fov(128, 128, 0.9);
    let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
    let bins = bin_and_sort(&p, &intr, TILE, 0.0);

    // Pick the densest few tiles.
    let mut order: Vec<usize> = (0..bins.tile_count()).collect();
    order.sort_by_key(|&t| std::cmp::Reverse(bins.list(t).len()));
    for &tile in order.iter().take(4) {
        let list = bins.list(tile);
        if list.is_empty() {
            continue;
        }
        let (ox, oy) = bins.tile_origin(tile);
        let means: Vec<[f32; 2]> = list.iter().map(|&i| p.means[i as usize]).collect();
        let conics: Vec<[f32; 3]> = list
            .iter()
            .map(|&i| {
                let c = p.conics[i as usize];
                [c.a, c.b, c.c]
            })
            .collect();
        let opacs: Vec<f32> = list.iter().map(|&i| p.opacity[i as usize]).collect();
        let colors: Vec<[f32; 3]> = list.iter().map(|&i| p.colors[i as usize]).collect();

        let carry = rt
            .raster_tile_full(&means, &conics, &opacs, &colors, [ox, oy])
            .expect("raster_tile_full");

        for (ly, lx) in [(0usize, 0usize), (7, 9), (15, 15), (3, 12)] {
            let px = ox + lx as f32 + 0.5;
            let py = oy + ly as f32 + 0.5;
            let (c_native, t_native, _, _, _) = composite_pixel(&p, list, px, py, 0);
            let off = ly * TILE + lx;
            let c_hlo = [
                carry.color[off * 3],
                carry.color[off * 3 + 1],
                carry.color[off * 3 + 2],
            ];
            let t_hlo = carry.transmittance[off];
            for ch in 0..3 {
                assert!(
                    (c_native[ch] - c_hlo[ch]).abs() < 2e-4,
                    "tile {tile} px ({lx},{ly}) ch {ch}: native {} vs hlo {}",
                    c_native[ch],
                    c_hlo[ch]
                );
            }
            assert!(
                (t_native - t_hlo).abs() < 2e-4,
                "tile {tile} px ({lx},{ly}): T native {t_native} vs hlo {t_hlo}"
            );
        }
    }
}

#[test]
fn sh_eval_matches_native() {
    let Some(rt) = runtime() else { return };
    let scene = test_scene(405, 64);
    let cam = Vec3::new(0.3, -0.2, -3.0);
    let dirs: Vec<[f32; 3]> = scene
        .pos
        .iter()
        .map(|&p| (p - cam).normalized().to_array())
        .collect();
    let coeffs: Vec<[[f32; 3]; SH_COEFFS]> = scene.sh.clone();
    let hlo = rt.sh_eval_chunk(&dirs, &coeffs).expect("sh_eval");
    for i in 0..scene.len() {
        let native = eval_color(scene.pos[i], cam, &scene.sh[i]);
        for ch in 0..3 {
            assert!(
                (native[ch] - hlo[i][ch]).abs() < 1e-5,
                "gaussian {i} ch {ch}: native {} vs hlo {}",
                native[ch],
                hlo[i][ch]
            );
        }
    }
}

#[test]
fn alpha_front_matches_native_alpha() {
    let Some(rt) = runtime() else { return };
    let scene = test_scene(406, 2000);
    let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
    let intr = Intrinsics::with_fov(64, 64, 0.9);
    let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
    let bins = bin_and_sort(&p, &intr, TILE, 0.0);
    let tile = (0..bins.tile_count())
        .max_by_key(|&t| bins.list(t).len())
        .unwrap();
    let list: Vec<u32> = bins.list(tile).iter().take(100).copied().collect();
    let (ox, oy) = bins.tile_origin(tile);
    let means: Vec<[f32; 2]> = list.iter().map(|&i| p.means[i as usize]).collect();
    let conics: Vec<[f32; 3]> = list
        .iter()
        .map(|&i| {
            let c = p.conics[i as usize];
            [c.a, c.b, c.c]
        })
        .collect();
    let opacs: Vec<f32> = list.iter().map(|&i| p.opacity[i as usize]).collect();
    let alphas = rt
        .alpha_front_chunk(&means, &conics, &opacs, [ox, oy])
        .expect("alpha_front");
    // Verify a scattering of (gaussian, pixel) pairs against the scalar
    // alpha formula.
    for &(g, ly, lx) in &[(0usize, 0usize, 0usize), (5, 8, 8), (40, 15, 3), (99, 4, 11)] {
        if g >= list.len() {
            continue;
        }
        let px = ox + lx as f32 + 0.5;
        let py = oy + ly as f32 + 0.5;
        let dx = px - means[g][0];
        let dy = py - means[g][1];
        let (a, b, c) = (conics[g][0], conics[g][1], conics[g][2]);
        let power = -0.5 * (a * dx * dx + c * dy * dy) - b * dx * dy;
        let expect = if power > 0.0 {
            0.0
        } else {
            (opacs[g] * power.exp()).min(lumina::constants::ALPHA_MAX)
        };
        let got = alphas[g * TILE * TILE + ly * TILE + lx];
        assert!(
            (got - expect).abs() < 1e-5,
            "alpha({g},{ly},{lx}): hlo {got} vs native {expect}"
        );
    }
}

#[test]
fn manifest_constants_agree_with_crate() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest_constants;
    assert!((m.alpha_min - lumina::constants::ALPHA_MIN).abs() < 1e-9);
    assert!((m.alpha_max - lumina::constants::ALPHA_MAX).abs() < 1e-9);
    assert!((m.t_eps - lumina::constants::T_EPS).abs() < 1e-12);
}
