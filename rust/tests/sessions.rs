//! SessionPool integration: shared-scene multi-session serving must be
//! correct, aggregated, and bitwise deterministic regardless of the
//! worker-thread count.

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::{PoolReport, SessionPool};
use lumina::util::par;

fn small_cfg(variant: HardwareVariant) -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 4000;
    c.camera.width = 64;
    c.camera.height = 64;
    c.camera.frames = 4;
    c.variant = variant;
    c
}

fn run_pool(variant: HardwareVariant, n: usize) -> PoolReport {
    SessionPool::new(small_cfg(variant), n).unwrap().run().unwrap()
}

#[test]
fn pool_serves_four_sessions_and_aggregates() {
    let report = run_pool(HardwareVariant::Lumina, 4);
    assert_eq!(report.sessions.len(), 4);
    assert_eq!(report.total_frames(), 16);
    assert!(report.aggregate_fps() > 0.0);
    assert!(report.host_fps() > 0.0);
    assert!(report.wall_s > 0.0);
    let s = report.summary();
    assert!(s.contains("4 sessions"), "summary: {s}");
    // Distinct camera seeds -> distinct trajectories -> the sessions do
    // different work.
    assert_ne!(report.sessions[0], report.sessions[1]);
    // Aggregate fps is the sum of per-session simulated fps.
    let sum: f64 = report.sessions.iter().map(|r| r.fps()).sum();
    assert!((report.aggregate_fps() - sum).abs() < 1e-12);
}

#[test]
fn pool_reuses_one_scene_allocation() {
    let pool = SessionPool::new(small_cfg(HardwareVariant::Gpu), 3).unwrap();
    let scenes: Vec<_> = pool.sessions().iter().map(|c| c.scene.clone()).collect();
    for w in scenes.windows(2) {
        assert!(std::sync::Arc::ptr_eq(&w[0], &w[1]), "sessions must share the scene");
    }
}

#[test]
fn pool_thread_split_wastes_no_workers() {
    // 8 threads / 3 sessions used to strand 2 workers (inner = 8/3 = 2
    // on every chunk); the remainder must be spread across the outer
    // chunks instead.
    for (total, sessions) in [(8usize, 3usize), (6, 4), (12, 5), (16, 16), (9, 2)] {
        let shares = par::split_budget(total, sessions);
        assert_eq!(shares.len(), sessions);
        assert_eq!(
            shares.iter().sum::<usize>(),
            total,
            "budget {total} over {sessions} sessions strands workers: {shares:?}"
        );
        assert!(shares.iter().all(|&s| s >= 1));
    }
}

#[test]
fn pool_bitwise_deterministic_across_thread_counts() {
    // Same configs + seeds must produce bitwise-identical per-session
    // reports whether the pool (and the tile rasterizer under it) runs
    // on 1 worker or many. Both runs happen inside one test so the
    // global override can't race a concurrent test.
    for variant in [HardwareVariant::Lumina, HardwareVariant::RcGpu] {
        par::set_num_threads(1);
        let serial = run_pool(variant, 3);
        par::set_num_threads(8);
        let parallel = run_pool(variant, 3);
        par::set_num_threads(0); // restore auto-detect
        assert_eq!(
            serial.sessions, parallel.sessions,
            "{variant:?}: thread count changed the reports"
        );
    }
}
