//! SessionPool integration: shared-scene multi-session serving must be
//! correct, aggregated, and bitwise deterministic regardless of the
//! worker-thread count.

use lumina::config::{HardwareVariant, LuminaConfig, Tier};
use lumina::coordinator::{Coordinator, PoolReport, SessionPool};
use lumina::util::par;

fn small_cfg(variant: HardwareVariant) -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 4000;
    c.camera.width = 64;
    c.camera.height = 64;
    c.camera.frames = 4;
    c.variant = variant;
    c
}

fn run_pool(variant: HardwareVariant, n: usize) -> PoolReport {
    SessionPool::builder(small_cfg(variant)).sessions(n).build().unwrap().run().unwrap()
}

#[test]
fn pool_serves_four_sessions_and_aggregates() {
    let report = run_pool(HardwareVariant::Lumina, 4);
    assert_eq!(report.sessions.len(), 4);
    assert_eq!(report.total_frames(), 16);
    assert!(report.aggregate_fps() > 0.0);
    assert!(report.host_fps() > 0.0);
    assert!(report.wall_s > 0.0);
    let s = report.summary();
    assert!(s.contains("4 sessions"), "summary: {s}");
    // Distinct camera seeds -> distinct trajectories -> the sessions do
    // different work.
    assert_ne!(report.sessions[0], report.sessions[1]);
    // Aggregate fps is the sum of per-session simulated fps.
    let sum: f64 = report.sessions.iter().map(|r| r.fps()).sum();
    assert!((report.aggregate_fps() - sum).abs() < 1e-12);
}

#[test]
fn pool_reuses_one_scene_allocation() {
    let pool = SessionPool::builder(small_cfg(HardwareVariant::Gpu)).sessions(3).build().unwrap();
    let scenes: Vec<_> = pool.sessions().iter().map(|c| c.scene.clone()).collect();
    for w in scenes.windows(2) {
        assert!(std::sync::Arc::ptr_eq(&w[0], &w[1]), "sessions must share the scene");
    }
}

#[test]
fn pool_thread_split_wastes_no_workers() {
    // 8 threads / 3 sessions used to strand 2 workers (inner = 8/3 = 2
    // on every chunk); the remainder must be spread across the outer
    // chunks instead.
    for (total, sessions) in [(8usize, 3usize), (6, 4), (12, 5), (16, 16), (9, 2)] {
        let shares = par::split_budget(total, sessions);
        assert_eq!(shares.len(), sessions);
        assert_eq!(
            shares.iter().sum::<usize>(),
            total,
            "budget {total} over {sessions} sessions strands workers: {shares:?}"
        );
        assert!(shares.iter().all(|&s| s >= 1));
    }
}

#[test]
fn pipelined_pool_bitwise_identical_to_synchronous_across_thread_counts() {
    // Depth-2 stage-level scheduling — frame N+1's frontend overlapping
    // frame N's raster — must be invisible in the output: bitwise equal
    // to the depth-1 baseline at every thread count.
    let run = |depth: usize, threads: usize| -> PoolReport {
        par::set_num_threads(threads);
        let mut cfg = small_cfg(HardwareVariant::Lumina);
        cfg.pool.pipeline_depth = depth;
        let r = SessionPool::builder(cfg).sessions(3).build().unwrap().run().unwrap();
        par::set_num_threads(0);
        r
    };
    let reference = run(1, 1);
    for threads in [1usize, 3, 8] {
        let depth2 = run(2, threads);
        assert_eq!(depth2.pipeline_depth, 2);
        assert_eq!(
            reference.sessions, depth2.sessions,
            "depth 2 @ {threads} threads diverged from the synchronous baseline"
        );
        let depth1 = run(1, threads);
        assert_eq!(
            reference.sessions, depth1.sessions,
            "depth 1 @ {threads} threads is thread-count dependent"
        );
    }
    // Every session rendered its whole trajectory.
    for r in &reference.sessions {
        assert_eq!(r.frames.len(), 4);
    }
}

#[test]
fn depth_three_pool_bitwise_identical_to_synchronous_across_thread_counts() {
    // Depth-3 chunk interleaving — two frames in flight, their raster
    // dispatched at RasterChunk granularity — must also be invisible:
    // bitwise equal to the depth-1 baseline at every thread count, for
    // both even and uneven sub-stage splits of the 16-tile frame.
    let run = |depth: usize, substages: usize, threads: usize| -> PoolReport {
        par::set_num_threads(threads);
        let mut cfg = small_cfg(HardwareVariant::Lumina);
        cfg.pool.pipeline_depth = depth;
        cfg.pool.raster_substages = substages;
        let r = SessionPool::builder(cfg).sessions(3).build().unwrap().run().unwrap();
        par::set_num_threads(0);
        r
    };
    let reference = run(1, 4, 1);
    for threads in [1usize, 2, 4] {
        let depth3 = run(3, 4, threads);
        assert_eq!(depth3.pipeline_depth, 3);
        assert_eq!(
            reference.sessions, depth3.sessions,
            "depth 3 @ {threads} threads diverged from the synchronous baseline"
        );
    }
    // Uneven split (16 tiles over 7 chunks) and the degenerate
    // single-chunk plan (depth 3 falls back to depth-2 scheduling).
    for substages in [7usize, 1] {
        let odd = run(3, substages, 4);
        assert_eq!(
            reference.sessions, odd.sessions,
            "depth 3 with {substages} sub-stages diverged"
        );
    }
}

#[test]
fn mid_run_set_tier_drains_in_flight_slot() {
    // Reference: synchronous session, tier swapped after two frames.
    let mut cfg = small_cfg(HardwareVariant::Lumina);
    cfg.pool.pipeline_depth = 1;
    let mut reference = Coordinator::new(cfg.clone()).unwrap();
    let mut want = Vec::new();
    for _ in 0..2 {
        want.push(reference.step().unwrap());
    }
    reference.set_tier(Tier::Half).unwrap();
    while reference.remaining() > 0 {
        want.push(reference.step().unwrap());
    }

    // Pipelined: the swap lands while frame 1 is mid-flight; the slot
    // must drain under the *old* tier and no frame may be lost,
    // reordered, or re-rendered.
    cfg.pool.pipeline_depth = 2;
    let mut c = Coordinator::new(cfg).unwrap();
    let mut got = Vec::new();
    assert!(c.step_pipelined().unwrap().is_none(), "priming dispatch");
    got.push(c.step_pipelined().unwrap().expect("frame 0 completes"));
    assert_eq!(c.in_flight(), 1, "frame 1 is mid-flight");
    c.set_tier(Tier::Half).unwrap();
    assert_eq!(c.in_flight(), 1, "drained frame 1 awaits pickup");
    while got.len() < want.len() {
        if let Some(f) = c.step_pipelined().unwrap() {
            got.push(f);
        }
    }
    assert_eq!(c.remaining(), 0);
    assert_eq!(c.in_flight(), 0);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.report, w.report, "frame {i} report diverged");
        assert_eq!(g.image.data, w.image.data, "frame {i} image diverged");
    }
    let tiers: Vec<&str> = got.iter().map(|f| f.report.tier).collect();
    assert_eq!(tiers, vec!["full", "full", "half", "half"]);
}

#[test]
fn mid_run_set_tier_drains_depth_three_queue() {
    // Reference: synchronous session, tier swapped after three frames
    // (at depth 3 the swap lands with frames 1 and 2 mid-flight, so
    // they must drain under the old tier).
    let mut cfg = small_cfg(HardwareVariant::Lumina);
    cfg.pool.pipeline_depth = 1;
    let mut reference = Coordinator::new(cfg.clone()).unwrap();
    let mut want = Vec::new();
    for _ in 0..3 {
        want.push(reference.step().unwrap());
    }
    reference.set_tier(Tier::Half).unwrap();
    while reference.remaining() > 0 {
        want.push(reference.step().unwrap());
    }

    // Depth 3: two priming dispatches, then frame 0 completes while
    // frame 1 is half-rastered and frame 2 just fed. The swap drains
    // both queued frames — including the mid-chunk one — under the old
    // tier; no frame may be lost, reordered, or re-rendered.
    cfg.pool.pipeline_depth = 3;
    cfg.pool.raster_substages = 4;
    let mut c = Coordinator::new(cfg).unwrap();
    let mut got = Vec::new();
    assert!(c.step_pipelined().unwrap().is_none(), "priming dispatch");
    assert!(c.step_pipelined().unwrap().is_none(), "second priming dispatch");
    got.push(c.step_pipelined().unwrap().expect("frame 0 completes"));
    assert_eq!(c.in_flight(), 2, "frames 1 and 2 are mid-flight");
    c.set_tier(Tier::Half).unwrap();
    assert_eq!(c.in_flight(), 2, "drained frames 1 and 2 await pickup");
    while got.len() < want.len() {
        if let Some(f) = c.step_pipelined().unwrap() {
            got.push(f);
        }
    }
    assert_eq!(c.remaining(), 0);
    assert_eq!(c.in_flight(), 0);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.report, w.report, "frame {i} report diverged");
        assert_eq!(g.image.data, w.image.data, "frame {i} image diverged");
    }
    let tiers: Vec<&str> = got.iter().map(|f| f.report.tier).collect();
    assert_eq!(tiers, vec!["full", "full", "full", "half"]);
}

#[test]
fn pool_bitwise_deterministic_across_thread_counts() {
    // Same configs + seeds must produce bitwise-identical per-session
    // reports whether the pool (and the tile rasterizer under it) runs
    // on 1 worker or many. Both runs happen inside one test so the
    // global override can't race a concurrent test.
    for variant in [HardwareVariant::Lumina, HardwareVariant::RcGpu] {
        par::set_num_threads(1);
        let serial = run_pool(variant, 3);
        par::set_num_threads(8);
        let parallel = run_pool(variant, 3);
        par::set_num_threads(0); // restore auto-detect
        assert_eq!(
            serial.sessions, parallel.sessions,
            "{variant:?}: thread count changed the reports"
        );
    }
}
