//! Shared cross-session radiance caching: the snapshot/merge cache
//! topology must be bitwise deterministic (across thread counts,
//! pipeline depths, and mid-run tier swaps), strictly improve hit rates
//! on convergent-pose pools over private per-session caches, and make
//! its lock/port-contention cost visible to admission pricing.

use lumina::config::{CacheScope, HardwareVariant, LuminaConfig, Tier};
use lumina::coordinator::admission::{
    price_stages, price_workload, SessionDemand, ADMISSION_HEADROOM,
    SHARED_HIT_RASTER_SAVINGS,
};
use lumina::coordinator::{AdmissionController, FrameReport, SessionPool};
use lumina::sim::lumincore::LuminCoreSim;
use lumina::util::par;

/// Tests that flip the global thread count serialize on this lock so
/// they cannot race each other inside one test binary.
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn shared_cfg() -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 4000;
    // 32x32 = one 4x4-tile cache group of 1024 pixels: the pool's
    // merged inserts stay well inside the 4096-entry bank, so the
    // cross-session entries survive pLRU instead of thrashing (the
    // capacity-pressure regime is exercised by fig24/benches, not
    // here).
    c.camera.width = 32;
    c.camera.height = 32;
    c.camera.frames = 6;
    c.pool.epoch_frames = 2;
    c.variant = HardwareVariant::Lumina;
    c.pool.cache_scope = CacheScope::Shared;
    c
}

/// A pool of `n` viewers converging on one camera path, staggered by
/// `stagger` frames (viewer `i` trails viewer `i+1`): after each epoch
/// merge the trailing viewers revisit poses the pool has already
/// cached. Private per-session caches cannot serve these hits; the
/// shared snapshot can — the workload the tentpole targets. Thin
/// wrapper over the staggered [`lumina::coordinator::PoolBuilder`]
/// configuration so the benches and these tests measure one workload.
fn convergent_pool(cfg: &LuminaConfig, n: usize, stagger: usize) -> SessionPool {
    SessionPool::builder(cfg.clone()).sessions(n).stagger(stagger).build().unwrap()
}

#[test]
fn shared_pool_bitwise_deterministic_across_threads_depths_and_tier_swaps() {
    let _lock = lock();
    // The acceptance contract: a shared-scope pool of 3 convergent
    // sessions is bitwise identical at 1/2/4 threads and at pipeline
    // depth 1 vs 2, including a mid-run set_tier (demotion to the
    // half-res grid and promotion back).
    let run = |threads: usize, depth: usize| -> Vec<Vec<FrameReport>> {
        par::set_num_threads(threads);
        let mut cfg = shared_cfg();
        cfg.pool.pipeline_depth = depth;
        let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
        let mut frames: Vec<Vec<FrameReport>> = vec![Vec::new(); 3];
        let mut collect = |frames: &mut Vec<Vec<FrameReport>>,
                           epoch: Vec<Vec<FrameReport>>| {
            for (i, f) in epoch.into_iter().enumerate() {
                frames[i].extend(f);
            }
        };
        collect(&mut frames, pool.run_epoch(2).unwrap());
        // Mid-run tier swap: session 1 drops to the half-res tile grid
        // (its delta is invalidated, the pool snapshots are untouched),
        // serves an epoch there, and is promoted back.
        pool.set_session_tier(1, Tier::Half).unwrap();
        collect(&mut frames, pool.run_epoch(2).unwrap());
        pool.set_session_tier(1, Tier::Full).unwrap();
        collect(&mut frames, pool.run_epoch(2).unwrap());
        par::set_num_threads(0);
        frames
    };
    let reference = run(1, 1);
    for (threads, depth) in [(2usize, 1usize), (4, 1), (1, 2), (2, 2), (4, 2)] {
        let got = run(threads, depth);
        assert_eq!(
            reference, got,
            "shared-scope pool diverged at {threads} threads, depth {depth}"
        );
    }
    for s in &reference {
        assert_eq!(s.len(), 6, "every session serves its whole trajectory");
    }
    let tiers: Vec<&str> = reference[1].iter().map(|f| f.tier).collect();
    assert_eq!(tiers, vec!["full", "full", "half", "half", "full", "full"]);
    // And the sharing is real: cross-session snapshot hits occurred.
    let snapshot_hits: u64 =
        reference.iter().flatten().map(|f| f.cache.snapshot_hits).sum();
    assert!(snapshot_hits > 0, "convergent shared pool produced no cross-session hits");
}

#[test]
fn shared_scope_strictly_beats_private_hit_rate_on_convergent_pool() {
    let cfg = shared_cfg();
    let mut private_cfg = cfg.clone();
    private_cfg.pool.cache_scope = CacheScope::Private;
    let stagger = cfg.pool.epoch_frames;

    let shared = convergent_pool(&cfg, 3, stagger).run().unwrap();
    let private = convergent_pool(&private_cfg, 3, stagger).run().unwrap();

    let sh = shared.cache_stats();
    let pr = private.cache_stats();
    assert!(pr.lookups > 0 && sh.lookups > 0);
    assert!(
        sh.hit_rate() > pr.hit_rate(),
        "shared scope must strictly beat private on convergent poses: \
         shared {:.4} vs private {:.4}",
        sh.hit_rate(),
        pr.hit_rate()
    );
    assert!(sh.snapshot_hits > 0, "the extra hits must come from the snapshot");
    assert_eq!(pr.snapshot_hits, 0, "private scope has no snapshot to hit");

    // Hit rates are surfaced per session and merged.
    assert!(shared.summary().contains("cache hit"), "summary: {}", shared.summary());
    for r in &shared.sessions {
        assert!(r.cache_hit_rate() >= 0.0);
    }
}

#[test]
fn contention_cost_reported_and_consumed_by_admission_pricing() {
    // LuminCore reports a nonzero shared-lookup contention cost...
    let sim = LuminCoreSim::paper_default();
    assert!(sim.shared_contention_s((64 * 64) as u64) > 0.0);

    // ...and a shared-scope measured workload prices strictly above its
    // private twin through the same seams admission planning uses.
    let cfg = shared_cfg();
    let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
    let demands = pool.probe_demands().unwrap();
    assert!(demands.iter().all(|d| d.cache_shared), "pool must mark shared demands");
    let w = &demands[0].workload;
    assert!(w.cache_shared, "workload must carry scope provenance");
    let mut private_twin = w.clone();
    private_twin.cache_shared = false;
    let shared_price = price_workload(w, HardwareVariant::Lumina);
    let private_price = price_workload(&private_twin, HardwareVariant::Lumina);
    assert!(
        shared_price > private_price,
        "contention must surface in the admission price: {shared_price} vs {private_price}"
    );
    // The scope flag survives the planner's normalized tier estimates,
    // so every ladder rung keeps paying the structural contention.
    let est = w.tier_estimate(Tier::Full, Tier::Reduced, 0.5);
    assert!(est.cache_shared, "normalization must keep the scope flag");
    assert!(est.cache_outcomes.is_none(), "stats are still stripped");
}

#[test]
fn warm_handoff_prices_late_joiner_with_pool_hit_rate() {
    // A viewer admitted mid-run attaches to the already-merged (warm)
    // snapshot, so its demand must be priced with the pool-wide
    // observed hit rate — cold pricing would refuse viewers the pool
    // actually holds. Mirror the planner's exact rung arithmetic to
    // pick a target between the cold-joiner and warm-joiner totals.
    use lumina::pipeline::stage::FrameWorkload;
    let cfg = shared_cfg();
    let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
    pool.run_epoch(2).unwrap();
    pool.run_epoch(2).unwrap();
    let rate = pool.pool_hit_rate();
    assert!(rate > 0.0, "convergent epochs must produce an observed hit rate");

    let price_at = |w: &FrameWorkload, rate: f64| {
        let est = w.tier_estimate(Tier::Full, Tier::Full, cfg.pool.reduced_fraction);
        let p = price_stages(&est, cfg.variant);
        p.front_s
            + p.discounted_raster_s(1.0 - rate.clamp(0.0, 1.0) * SHARED_HIT_RASTER_SAVINGS)
    };
    let demand = |w: &FrameWorkload, rate: f64| SessionDemand {
        workload: w.clone(),
        tier: Tier::Full,
        variant: cfg.variant,
        half_capable: true,
        priority: 1.0,
        cache_shared: true,
        cache_world: false,
        pool_hit_rate: rate,
        sort_clustered: false,
        sort_sharers: 1,
        sort_leader: true,
    };

    let active: Vec<FrameWorkload> = pool
        .sessions()
        .iter()
        .map(|c| c.last_workload().unwrap().clone())
        .collect();
    // The joiner's probe workload: its first convergent pose, same as
    // the pool's own first frame shape — session 0's current record is
    // a fine stand-in since all demands go through the same pricing.
    let joiner_w = active[0].clone();
    let active_total: f64 = active.iter().map(|w| price_at(w, rate)).sum();
    let joiner_cold = price_at(&joiner_w, 0.0);
    let joiner_warm = price_at(&joiner_w, rate);
    assert!(joiner_warm < joiner_cold, "the warm discount must bite");
    let budget_mid = active_total + (joiner_cold + joiner_warm) / 2.0;
    let target = (1.0 - ADMISSION_HEADROOM) / budget_mid;
    let ctrl = AdmissionController::new(target, vec![Tier::Full], 0.5).unwrap();

    let mut demands: Vec<SessionDemand> =
        active.iter().map(|w| demand(w, rate)).collect();
    demands.push(demand(&joiner_w, 0.0)); // pre-handoff behavior: cold
    assert!(ctrl.plan(&demands).is_err(), "cold joiner pricing must refuse");
    demands.pop();
    demands.push(demand(&joiner_w, rate)); // warm handoff
    let plan = ctrl.plan(&demands).unwrap();
    assert_eq!(plan.tiers, vec![Tier::Full; 4], "warm joiner admits at full");
}

#[test]
fn admit_joins_warm_pool_mid_run_and_refuses_cleanly() {
    // End to end through SessionPool::admit: a convergent late joiner
    // enters a served pool, inherits the shared snapshot, and renders
    // cross-session hits from its first epoch; an impossible target
    // refuses and leaves the pool untouched.
    let cfg = shared_cfg();
    let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
    pool.run_epoch(2).unwrap();
    pool.run_epoch(2).unwrap();
    assert!(pool.pool_hit_rate() > 0.0);

    let join_cfg = pool.sessions()[0].cfg.clone();
    let impossible = AdmissionController::new(1e9, vec![Tier::Full], 0.5).unwrap();
    assert!(pool.admit(join_cfg.clone(), &impossible).is_err());
    assert_eq!(pool.len(), 3, "a refused joiner must not enter the pool");

    let generous =
        AdmissionController::new(1e-3, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)
            .unwrap();
    let idx = pool.admit(join_cfg, &generous).unwrap();
    assert_eq!(idx, 3);
    assert_eq!(pool.len(), 4);
    let epoch = pool.run_epoch(2).unwrap();
    assert_eq!(epoch[3].len(), 2, "the admitted session serves the next epoch");
    let joiner_hits: u64 = epoch[3].iter().map(|f| f.cache.snapshot_hits).sum();
    assert!(
        joiner_hits > 0,
        "a convergent late joiner must hit the pool's warm snapshot immediately"
    );
}

#[test]
fn shared_pool_serves_under_admission_control() {
    let _lock = lock();
    // End to end through SessionPool::serve: epoch merges interleave
    // with re-planning, and the run stays thread-count deterministic.
    let cfg = shared_cfg();
    let cost = {
        let mut probe = SessionPool::builder(cfg.clone()).build().unwrap();
        let demands = probe.probe_demands().unwrap();
        price_workload(&demands[0].workload, cfg.variant)
    };
    // Generous target: everyone stays full; the point here is the
    // serve-path merge plumbing, not demotion.
    let target = (1.0 - ADMISSION_HEADROOM) / (6.0 * cost);
    let run = |threads: usize| {
        par::set_num_threads(threads);
        let ctrl =
            AdmissionController::new(target, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)
                .unwrap();
        let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
        let r = pool.serve(&ctrl).unwrap();
        par::set_num_threads(0);
        r
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(
        serial.sessions, parallel.sessions,
        "thread count changed a shared-scope admission-controlled run"
    );
    assert_eq!(serial.total_frames(), 18);
    assert!(
        serial.cache_stats().snapshot_hits > 0,
        "served epochs must merge and cross-hit"
    );
}
