//! Pool-wide work-stealing scheduler integration: `pool.scheduler =
//! "stealing"` must be an *invisible* optimization — bitwise-identical
//! rendered frames, reports, and loadtest JSON vs the per-session
//! scheduler, at 1, 2, and 4 worker threads, through mid-run tier swaps,
//! retirement churn, and depth-3 raster sub-staging.

use lumina::config::{HardwareVariant, LuminaConfig, SchedulerMode, Tier};
use lumina::coordinator::{FrameResult, SessionPool};
use lumina::util::par;
use lumina::workload::{run_loadtest, LoadtestOptions, Scenario};

/// Tests that flip the global thread count serialize on this lock so
/// they cannot race each other inside one test binary.
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn small_cfg(depth: usize) -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 3000;
    c.camera.width = 48;
    c.camera.height = 48;
    c.camera.frames = 6;
    c.variant = HardwareVariant::Lumina;
    c.pool.pipeline_depth = depth;
    c.pool.epoch_frames = 2;
    c
}

fn pool_with(cfg: &LuminaConfig, scheduler: SchedulerMode, n: usize) -> SessionPool {
    let mut cfg = cfg.clone();
    cfg.pool.scheduler = scheduler;
    SessionPool::builder(cfg).sessions(n).build().unwrap()
}

/// Drive a pool to completion in epochs, returning every completed
/// frame (image included) grouped by epoch and session.
fn run_all_epochs(pool: &mut SessionPool, ef: usize) -> Vec<Vec<Vec<FrameResult>>> {
    let mut epochs = Vec::new();
    while pool.sessions().iter().any(|c| c.remaining() > 0 || c.in_flight() > 0) {
        epochs.push(pool.run_epoch_results(ef).unwrap());
    }
    epochs
}

fn assert_epochs_bitwise_equal(
    want: &[Vec<Vec<FrameResult>>],
    got: &[Vec<Vec<FrameResult>>],
    ctx: &str,
) {
    assert_eq!(want.len(), got.len(), "{ctx}: epoch count");
    for (e, (we, ge)) in want.iter().zip(got).enumerate() {
        assert_eq!(we.len(), ge.len(), "{ctx}: epoch {e} session count");
        for (s, (ws, gs)) in we.iter().zip(ge).enumerate() {
            assert_eq!(ws.len(), gs.len(), "{ctx}: epoch {e} session {s} frames");
            for (w, g) in ws.iter().zip(gs) {
                assert_eq!(w.report, g.report, "{ctx}: epoch {e} session {s} report");
                assert_eq!(
                    w.image.data, g.image.data,
                    "{ctx}: epoch {e} session {s} frame {} image bits",
                    w.report.frame
                );
            }
        }
    }
}

#[test]
fn stealing_renders_bitwise_identical_frames_at_any_thread_count() {
    let _lock = lock();
    for depth in [1usize, 2] {
        let cfg = small_cfg(depth);
        // Reference: the per-session scheduler on one thread.
        par::set_num_threads(1);
        let want = run_all_epochs(&mut pool_with(&cfg, SchedulerMode::Session, 3), 2);
        par::set_num_threads(0);
        for threads in [1usize, 2, 4] {
            par::set_num_threads(threads);
            let got = run_all_epochs(&mut pool_with(&cfg, SchedulerMode::Stealing, 3), 2);
            par::set_num_threads(0);
            assert_epochs_bitwise_equal(
                &want,
                &got,
                &format!("depth {depth}, stealing @ {threads} threads"),
            );
        }
    }
}

#[test]
fn stealing_matches_session_at_depth_three_with_substages() {
    let _lock = lock();
    let mut cfg = small_cfg(3);
    cfg.pool.raster_substages = 3;
    par::set_num_threads(4);
    let want = run_all_epochs(&mut pool_with(&cfg, SchedulerMode::Session, 2), 2);
    let got = run_all_epochs(&mut pool_with(&cfg, SchedulerMode::Stealing, 2), 2);
    par::set_num_threads(0);
    assert_epochs_bitwise_equal(&want, &got, "depth 3 with raster sub-stages");
}

#[test]
fn stealing_survives_midrun_tier_swap_and_retirement_bitwise() {
    let _lock = lock();
    let cfg = small_cfg(2);
    // The same mid-run churn script under both schedulers: one epoch,
    // then demote session 1 (drains its frame slots into `drained`,
    // exercising the inline zero-work delivery) and retire session 0
    // (index shift), then run out the rest.
    let script = |scheduler: SchedulerMode| {
        let mut pool = pool_with(&cfg, scheduler, 3);
        let mut epochs = vec![pool.run_epoch_results(2).unwrap()];
        pool.set_session_tier(1, Tier::Reduced).unwrap();
        let retired = pool.retire(0).unwrap();
        epochs.extend(run_all_epochs(&mut pool, 2));
        (epochs, retired)
    };
    par::set_num_threads(4);
    let (want, want_retired) = script(SchedulerMode::Session);
    let (got, got_retired) = script(SchedulerMode::Stealing);
    par::set_num_threads(0);
    assert_eq!(want_retired, got_retired, "retire must drain identical frames");
    assert_epochs_bitwise_equal(&want, &got, "mid-run tier swap + retirement");
}

#[test]
fn stealing_loadtest_json_is_byte_identical_across_thread_counts() {
    let _lock = lock();
    let mut base = LuminaConfig::quick_test();
    base.scene.count = 2500;
    base.camera.width = 32;
    base.camera.height = 32;
    base.pool.epoch_frames = 2;
    let opts = |scheduler: &str| LoadtestOptions {
        scenario: Scenario::FlashCrowd,
        seed: 7,
        epochs: Some(3),
        smoke: true,
        overrides: vec![format!("pool.scheduler={scheduler}")],
    };
    par::set_num_threads(1);
    let reference = run_loadtest(base.clone(), &opts("session")).unwrap().to_json();
    par::set_num_threads(0);
    for threads in [1usize, 2, 4] {
        par::set_num_threads(threads);
        let steal = run_loadtest(base.clone(), &opts("stealing")).unwrap();
        par::set_num_threads(0);
        assert_eq!(
            reference,
            steal.to_json(),
            "stealing loadtest JSON diverged from the session scheduler at {threads} threads"
        );
        // The occupancy model is epoch-shape arithmetic, so it is as
        // thread-invariant as the report itself.
        assert!(steal.steal_idle_worker_frames <= steal.session_idle_worker_frames);
    }
}
