//! World-space hash radiance cache: the pool-wide snapshot must stay
//! bitwise deterministic across thread counts, pipeline depths, both
//! schedulers, and mid-run `set_tier`/`admit`/`retire`; its keys must
//! survive the resolution split that partitions geometry-keyed sharing;
//! and its probe-chain length, decay sweeps, and cross-tier hit-rate
//! discount must all surface through the admission-pricing seams.

use lumina::config::{CacheScope, HardwareVariant, LuminaConfig, SchedulerMode, Tier};
use lumina::coordinator::admission::{
    price_stages, price_workload, SessionDemand, ADMISSION_HEADROOM,
    SHARED_HIT_RASTER_SAVINGS,
};
use lumina::coordinator::{AdmissionController, FrameReport, SessionPool};
use lumina::lumina::rc::CacheStats;
use lumina::util::par;

/// Tests that flip the global thread count serialize on this lock so
/// they cannot race each other inside one test binary.
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn world_cfg() -> LuminaConfig {
    let mut c = LuminaConfig::quick_test();
    c.scene.count = 4000;
    c.camera.width = 32;
    c.camera.height = 32;
    c.camera.frames = 6;
    c.pool.epoch_frames = 2;
    c.variant = HardwareVariant::Lumina;
    c.pool.cache_scope = CacheScope::World;
    c
}

/// A pool of `n` viewers converging on one camera path, staggered by
/// `stagger` frames — the trailing viewers revisit world cells the pool
/// has already cached (same workload shape as `tests/shared_cache.rs`,
/// so the two scopes are compared on one footing).
fn convergent_pool(cfg: &LuminaConfig, n: usize, stagger: usize) -> SessionPool {
    SessionPool::builder(cfg.clone()).sessions(n).stagger(stagger).build().unwrap()
}

#[test]
fn world_pool_bitwise_deterministic_through_full_lifecycle() {
    let _lock = lock();
    // The acceptance contract: a world-scope pool is bitwise identical
    // across 1/2/4 threads, pipeline depths 1-3, and both schedulers,
    // through a mid-run demotion + promotion, a late-joiner admit, and
    // a retire (which drops the departing session's un-merged delta).
    let run = |threads: usize,
               depth: usize,
               scheduler: SchedulerMode|
     -> Vec<Vec<Vec<FrameReport>>> {
        par::set_num_threads(threads);
        let mut cfg = world_cfg();
        cfg.pool.pipeline_depth = depth;
        cfg.pool.scheduler = scheduler;
        let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
        let mut out = Vec::new();
        out.push(pool.run_epoch(2).unwrap());
        // Mid-run tier swap: the world snapshot carries no tile
        // geometry, so the demoted session re-attaches to the *same*
        // pool snapshot (only its private delta is dropped).
        pool.set_session_tier(1, Tier::Half).unwrap();
        out.push(pool.run_epoch(2).unwrap());
        pool.set_session_tier(1, Tier::Full).unwrap();
        // A convergent late joiner enters the warm pool...
        let join_cfg = pool.sessions()[0].cfg.clone();
        let generous = AdmissionController::new(
            1e-3,
            cfg.pool.tiers.clone(),
            cfg.pool.reduced_fraction,
        )
        .unwrap();
        assert_eq!(pool.admit(join_cfg, &generous).unwrap(), 3);
        // ...and the first viewer leaves mid-epoch-cycle.
        out.push(vec![pool.retire(0).unwrap()]);
        out.push(pool.run_epoch(2).unwrap());
        out.push(pool.run_epoch(2).unwrap());
        out.push(pool.run_epoch(2).unwrap());
        par::set_num_threads(0);
        out
    };
    let reference = run(1, 1, SchedulerMode::Session);
    for (threads, depth, scheduler) in [
        (2usize, 1usize, SchedulerMode::Session),
        (4, 1, SchedulerMode::Session),
        (1, 2, SchedulerMode::Session),
        (4, 2, SchedulerMode::Session),
        (2, 3, SchedulerMode::Session),
        (1, 1, SchedulerMode::Stealing),
        (4, 2, SchedulerMode::Stealing),
        (4, 3, SchedulerMode::Stealing),
    ] {
        let got = run(threads, depth, scheduler);
        assert_eq!(
            reference,
            got,
            "world-scope pool diverged at {threads} threads, depth {depth}, {} scheduler",
            scheduler.label()
        );
    }
    // The gauntlet really happened: the demoted session served a
    // half-res epoch and came back full.
    let tiers: Vec<&str> = reference[1][1].iter().map(|f| f.tier).collect();
    assert_eq!(tiers, vec!["half", "half"]);
    let back: Vec<&str> = reference[3][0].iter().map(|f| f.tier).collect();
    assert_eq!(back, vec!["full", "full"]);
    // And the sharing is real: cross-session snapshot hits occurred.
    let snapshot_hits: u64 = reference
        .iter()
        .flatten()
        .flatten()
        .map(|f| f.cache.snapshot_hits)
        .sum();
    assert!(snapshot_hits > 0, "convergent world pool produced no cross-session hits");
}

#[test]
fn world_scope_survives_resolution_split_geometry_scope_partitions() {
    // One session demoted to half-res before the first frame: under the
    // geometry-keyed scope it bins a different tile grid and can only
    // hit its own merged entries, while the world scope keeps all three
    // viewers on one snapshot — the bench gate's `world >= geom_shared`
    // invariant, asserted end to end.
    let run = |scope: CacheScope| -> (CacheStats, CacheStats) {
        let mut cfg = world_cfg();
        cfg.pool.cache_scope = scope;
        let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
        pool.set_session_tier(2, Tier::Half).unwrap();
        let mut pool_stats = CacheStats::default();
        let mut half_stats = CacheStats::default();
        for _ in 0..3 {
            for (i, frames) in pool.run_epoch(2).unwrap().into_iter().enumerate() {
                for f in frames {
                    pool_stats.merge(&f.cache);
                    if i == 2 {
                        half_stats.merge(&f.cache);
                    }
                }
            }
        }
        (pool_stats, half_stats)
    };
    let (world, world_half) = run(CacheScope::World);
    let (geom, geom_half) = run(CacheScope::Shared);
    assert!(world.lookups > 0 && geom.lookups > 0);
    assert!(
        world.hit_rate() >= geom.hit_rate(),
        "world keys must survive the resolution split: world {:.4} vs geometry-shared {:.4}",
        world.hit_rate(),
        geom.hit_rate()
    );
    assert!(
        world_half.snapshot_hits > 0,
        "the half-res viewer must hit the pool's world entries"
    );
    assert!(
        world_half.snapshot_hits >= geom_half.snapshot_hits,
        "the half-res viewer must gain from the pool-wide snapshot: \
         world {} vs geometry-shared {}",
        world_half.snapshot_hits,
        geom_half.snapshot_hits
    );
    assert!(world.probes_recorded() > 0, "frozen probes must be histogrammed");
    assert_eq!(geom.probes_recorded(), 0, "geometry scopes never chain");
}

#[test]
fn world_decay_provenance_surfaces_in_pool_report() {
    // Lifetime 1: anything not re-hit in the very next epoch is freed,
    // so a moving convergent pool must record decay evictions — and the
    // report/summary must surface them with the probe histogram.
    let mut cfg = world_cfg();
    cfg.pool.world_lifetime = 1;
    let report = convergent_pool(&cfg, 3, cfg.pool.epoch_frames).run().unwrap();
    assert!(report.decay_evictions > 0, "lifetime-1 pool must decay-evict");
    assert!(report.cache_stats().probes_recorded() > 0);
    let s = report.summary();
    assert!(s.contains("world probe"), "summary: {s}");
    assert!(s.contains("decayed"), "summary: {s}");
    // The default lifetime keeps the provenance quiet on the geometry
    // scopes: a shared-scope pool reports no decay and no chains.
    let mut geom_cfg = world_cfg();
    geom_cfg.pool.cache_scope = CacheScope::Shared;
    let geom_report = convergent_pool(&geom_cfg, 3, geom_cfg.pool.epoch_frames)
        .run()
        .unwrap();
    assert_eq!(geom_report.decay_evictions, 0);
    assert!(!geom_report.summary().contains("world probe"));
}

#[test]
fn world_demands_price_probe_chains_and_keep_discount_across_tiers() {
    // Pricing seams, end to end: world demands carry scope provenance
    // and the probe-chain multiplier, and the pool-hit-rate discount
    // transfers to the geometry-changing half rung — which the
    // geometry-keyed scope must keep pricing cold.
    let cfg = world_cfg();
    let mut pool = convergent_pool(&cfg, 3, cfg.pool.epoch_frames);
    pool.run_epoch(2).unwrap();
    pool.run_epoch(2).unwrap();
    let rate = pool.pool_hit_rate();
    assert!(rate > 0.0, "convergent epochs must produce an observed hit rate");
    let demands = pool.probe_demands().unwrap();
    assert!(
        demands.iter().all(|d| d.cache_shared && d.cache_world),
        "world demands must carry both scope flags"
    );
    let w = &demands[0].workload;
    assert_eq!(w.shared_probe_len, cfg.pool.world_probe_len as u32);
    // Probe chains are priced: a probe-1 twin is strictly cheaper.
    let mut short = w.clone();
    short.shared_probe_len = 1;
    assert!(
        price_workload(w, cfg.variant) > price_workload(&short, cfg.variant),
        "the probe-chain bound must multiply the shared-lookup price"
    );

    // Mirror the planner's exact half-rung arithmetic (depth-1
    // controller: front + raster) to pick a budget between the warm
    // (discounted) and cold prices.
    let est = w.tier_estimate(Tier::Full, Tier::Half, cfg.pool.reduced_fraction);
    let p = price_stages(&est, cfg.variant);
    let cold = p.front_s + p.raster_s;
    let warm = p.front_s
        + p.discounted_raster_s(1.0 - rate.clamp(0.0, 1.0) * SHARED_HIT_RASTER_SAVINGS);
    assert!(warm < cold, "the warm discount must bite on the half rung");
    let target = (1.0 - ADMISSION_HEADROOM) / ((cold + warm) / 2.0);
    let ctrl =
        AdmissionController::new(target, vec![Tier::Half], cfg.pool.reduced_fraction)
            .unwrap();
    let mk = |cache_world: bool| SessionDemand {
        workload: w.clone(),
        tier: Tier::Full,
        variant: cfg.variant,
        half_capable: true,
        priority: 1.0,
        cache_shared: true,
        cache_world,
        pool_hit_rate: rate,
        sort_clustered: false,
        sort_sharers: 1,
        sort_leader: true,
    };
    assert!(
        ctrl.plan(&[mk(false)]).is_err(),
        "the geometry-keyed scope must price the geometry-changing rung cold"
    );
    let plan = ctrl.plan(&[mk(true)]).unwrap();
    assert_eq!(plan.tiers, vec![Tier::Half], "world keys keep the discount across tiers");
}
