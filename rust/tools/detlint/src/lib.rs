//! detlint — the Lumina workspace's determinism static-analysis pass.
//!
//! Every seam the crate ships — thread-count-invariant rendering, the
//! epoch snapshot/merge cache, clustered sorting, the parallel scatter —
//! rests on one invariant: output is bitwise identical regardless of
//! thread count, scope, or pipeline depth. The dynamic 1/2/4-thread
//! comparison tests check that invariant on the inputs they run; this
//! pass checks for the *sources* of nondeterminism they cannot prove
//! absent, as four codebase-specific rules:
//!
//! * **R1 `hash-order-iter`** — no order-dependent iteration over
//!   `HashMap`/`HashSet` (`iter`, `keys`, `values`, `drain`, `retain`,
//!   `into_iter`, `for .. in map`, ...) in the render-path modules
//!   (`pipeline/`, `lumina/`, `coordinator/`, `scene/`). Hash iteration
//!   order is seeded per-process; anything it feeds diverges run to run.
//!   Probe-only maps (`get`/`insert`/`entry`) are fine and unflagged.
//! * **R2 `wall-clock`** — no `Instant::now` / `SystemTime` reads
//!   outside `util/bench.rs`; a frame-math path that reads the clock is
//!   timing-dependent by construction. Measurement sites that only
//!   *report* (never feed results back into rendering) carry an
//!   explicit annotation.
//! * **R3 `missing-safety`** — every `unsafe` block, `unsafe impl`, and
//!   `unsafe fn` carries a `// SAFETY:` comment stating the argument it
//!   relies on (for this crate: always a disjoint-writes argument).
//! * **R4 `thread-count`** — no `par::num_threads()` (or
//!   `available_parallelism`) reads outside `util/par.rs`, so render
//!   math cannot branch on worker count. Scheduling sites that only
//!   split budgets are annotated.
//!
//! **Suppression contract:** a violation is silenced only by an
//! adjacent comment of the form
//! `detlint: allow(<rule>[, <rule>...]) -- <justification>` on the same
//! line or in the contiguous comment block immediately above. The
//! justification text is mandatory; a malformed or unjustified
//! annotation is itself a violation (`bad-annotation`), and unknown
//! rule names are rejected. `#[cfg(test)]` modules are exempt from
//! R1/R2/R4 (determinism tests legitimately read clocks and thread
//! counts); R3 applies everywhere.
//!
//! The scanner is lexical, not an AST walk: it strips comments and
//! string/char literals with a small state machine, tracks
//! `#[cfg(test)]` regions by brace depth, and resolves hash-typed
//! identifiers from same-file declarations. That is deliberately the
//! right weight: the rules need *type* information to be exact, which
//! no syntax-only AST has either — and the failure mode of a lexical
//! false positive is an annotated suppression with a written
//! justification, which is exactly the audit trail the pass exists to
//! create. Fixtures under `tests/fixtures/` pin one seeded violation
//! per rule plus a clean tree, and the self-test asserts `rust/src`
//! scans clean.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// R1: order-dependent iteration over a hash collection in a
/// render-path module.
pub const RULE_HASH_ITER: &str = "hash-order-iter";
/// R2: wall-clock read outside `util/bench.rs`.
pub const RULE_WALL_CLOCK: &str = "wall-clock";
/// R3: `unsafe` site without a `// SAFETY:` comment.
pub const RULE_MISSING_SAFETY: &str = "missing-safety";
/// R4: worker-count read outside `util/par.rs`.
pub const RULE_THREAD_COUNT: &str = "thread-count";
/// A malformed or unjustified `detlint: allow(..)` annotation.
pub const RULE_BAD_ANNOTATION: &str = "bad-annotation";

/// The suppressible rules (`bad-annotation` cannot be allowed away).
pub const RULES: [&str; 4] =
    [RULE_HASH_ITER, RULE_WALL_CLOCK, RULE_MISSING_SAFETY, RULE_THREAD_COUNT];

/// Directories (as path components) whose files are on the render path
/// and therefore in scope for R1.
const RENDER_PATH_DIRS: [&str; 4] = ["pipeline", "lumina", "coordinator", "scene"];

/// Iteration methods whose order observes hash layout.
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// One rule violation at a file:line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    /// 1-indexed.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// One source line after literal/comment stripping.
#[derive(Debug, Default, Clone)]
struct Line {
    /// Code with comments and string/char-literal contents blanked and
    /// non-ASCII replaced by spaces (identifiers are ASCII-only in this
    /// workspace; `lib.rs` denies `non_ascii_idents`).
    code: String,
    /// Concatenated comment text of the line.
    comment: String,
}

fn is_word_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_ascii_alphanumeric() || chars[i - 1] == '_')
}

/// `r"..."` / `r#"..."#` / `br".."` opener at `i`: (prefix length
/// including the opening quote, hash count).
fn raw_str_open(chars: &[char], i: usize) -> Option<(usize, u32)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j - i + 1, hashes))
    } else {
        None
    }
}

/// Distinguish a char literal from a lifetime at a `'`.
fn is_char_literal(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(_) => chars.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// Split `source` into per-line code/comment with literals blanked.
fn strip(source: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        Chr,
    }
    let chars: Vec<char> = source.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((len, hashes)) = raw_str_open(&chars, i) {
                        st = St::RawStr(hashes);
                        cur.code.push(' ');
                        i += len;
                    } else if c == 'b' && chars.get(i + 1) == Some(&'"') {
                        st = St::Str;
                        cur.code.push(' ');
                        i += 2;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' && is_char_literal(&chars, i) {
                    st = St::Chr;
                    cur.code.push(' ');
                    i += 1;
                } else {
                    cur.code.push(if c.is_ascii() { c } else { ' ' });
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if depth <= 1 { St::Code } else { St::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    if chars.get(i + 1) == Some(&'\n') {
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2;
                } else if c == '"' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let h = hashes as usize;
                    let closed = (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closed {
                        st = St::Code;
                        i += 1 + h;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            St::Chr => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Mark the line ranges of `#[cfg(test)]`-gated items (brace-tracked).
fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        let code = &lines[i].code;
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut j = i;
            while j < lines.len() {
                for ch in lines[j].code.chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                mask[j] = true;
                if opened && depth <= 0 {
                    break;
                }
                if !opened && j >= i + 5 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

/// Byte offsets of standalone-word occurrences of `word` in `s`.
fn word_positions(s: &str, word: &str) -> Vec<usize> {
    let sb = s.as_bytes();
    let wlen = word.len();
    let mut out = Vec::new();
    if wlen == 0 || sb.len() < wlen {
        return out;
    }
    let mut start = 0usize;
    while let Some(rel) = s[start..].find(word) {
        let p = start + rel;
        let before_ok = p == 0 || !is_word_byte(sb[p - 1]);
        let after_ok = p + wlen >= sb.len() || !is_word_byte(sb[p + wlen]);
        if before_ok && after_ok {
            out.push(p);
        }
        start = p + 1;
    }
    out
}

/// Comments attached to line `idx`: its own trailing comment plus the
/// contiguous comment-only block immediately above (blank lines and
/// code lines both end the block).
fn attached_comments<'a>(lines: &'a [Line], idx: usize) -> Vec<&'a str> {
    let mut out = Vec::new();
    if !lines[idx].comment.trim().is_empty() {
        out.push(lines[idx].comment.as_str());
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.code.trim().is_empty() && !l.comment.trim().is_empty() {
            out.push(l.comment.as_str());
        } else {
            break;
        }
    }
    out
}

/// A parsed `detlint: allow(...)` annotation.
struct AllowSpec {
    rules: Vec<String>,
    justified: bool,
}

/// Parse the annotation in a comment, if any. `Some(Err(..))` is a
/// malformed annotation (reported as `bad-annotation`).
fn parse_allow(comment: &str) -> Option<Result<AllowSpec, String>> {
    let pos = comment.find("detlint:")?;
    let rest = comment[pos + "detlint:".len()..].trim_start();
    let body = match rest.strip_prefix("allow(") {
        Some(b) => b,
        None => return Some(Err("expected `allow(<rule>, ...)` after `detlint:`".to_string())),
    };
    let close = match body.find(')') {
        Some(c) => c,
        None => return Some(Err("unclosed `allow(` annotation".to_string())),
    };
    let rules: Vec<String> = body[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(Err("`allow()` names no rules".to_string()));
    }
    for r in &rules {
        if !RULES.contains(&r.as_str()) {
            return Some(Err(format!("unknown rule `{r}` (known: {})", RULES.join(", "))));
        }
    }
    let tail = body[close + 1..].trim_start();
    let justified = match tail.strip_prefix("--") {
        Some(j) => !j.trim().is_empty(),
        None => false,
    };
    Some(Ok(AllowSpec { rules, justified }))
}

/// Is `rule` suppressed at line `idx` by a justified annotation?
fn allowed(lines: &[Line], idx: usize, rule: &str) -> bool {
    attached_comments(lines, idx).iter().any(|c| match parse_allow(c) {
        Some(Ok(spec)) => spec.justified && spec.rules.iter().any(|r| r == rule),
        _ => false,
    })
}

fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    attached_comments(lines, idx).iter().any(|c| c.contains("SAFETY:"))
}

fn in_render_path(rel: &str) -> bool {
    RENDER_PATH_DIRS.iter().any(|d| {
        rel.starts_with(&format!("{d}/")) || rel.contains(&format!("/{d}/"))
    })
}

/// The identifier declared as `name: ..Hash..` left of a hash-type
/// occurrence at `hash_pos` (fields, fn params). Backward scan for a
/// single `:` (skipping `::`), bounded by statement punctuation.
fn decl_ident_before(code: &str, hash_pos: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut i = hash_pos;
    while i > 0 {
        i -= 1;
        match b[i] {
            b';' | b'{' | b'}' | b'=' | b'(' | b',' => return None,
            b':' => {
                if i > 0 && b[i - 1] == b':' {
                    i -= 1;
                    continue;
                }
                let mut j = i;
                while j > 0 && b[j - 1] == b' ' {
                    j -= 1;
                }
                let mut k = j;
                while k > 0 && is_word_byte(b[k - 1]) {
                    k -= 1;
                }
                if k < j {
                    return Some(code[k..j].to_string());
                }
                return None;
            }
            _ => {}
        }
    }
    None
}

/// Identifiers declared with a `HashMap`/`HashSet` type anywhere in the
/// file (let bindings, struct fields, fn params).
fn hash_idents(lines: &[Line]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let mut push = |n: String, names: &mut Vec<String>| {
        if !n.is_empty() && !names.contains(&n) {
            names.push(n);
        }
    };
    for l in lines {
        let code = &l.code;
        let mut positions = word_positions(code, "HashMap");
        positions.extend(word_positions(code, "HashSet"));
        if positions.is_empty() {
            continue;
        }
        let t = code.trim_start();
        if let Some(rest) = t.strip_prefix("let ") {
            let rest = rest.trim_start();
            let rest = rest.strip_prefix("mut ").unwrap_or(rest);
            let name: String =
                rest.chars().take_while(|c| c.is_ascii() && is_word_byte(*c as u8)).collect();
            push(name, &mut names);
        }
        for &p in &positions {
            if let Some(name) = decl_ident_before(code, p) {
                push(name, &mut names);
            }
        }
    }
    names
}

fn violation(rel: &str, idx: usize, rule: &'static str, message: String) -> Violation {
    Violation { file: rel.to_string(), line: idx + 1, rule, message }
}

/// R1: hash-order iteration in render-path modules.
fn rule_hash_iter(rel: &str, lines: &[Line], mask: &[bool], out: &mut Vec<Violation>) {
    if !in_render_path(rel) {
        return;
    }
    let idents = hash_idents(lines);
    if idents.is_empty() {
        return;
    }
    for (idx, l) in lines.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        for name in &idents {
            for p in word_positions(&l.code, name) {
                let rest = l.code[p + name.len()..].trim_start();
                if let Some(m) = rest.strip_prefix('.') {
                    let meth: String = m
                        .trim_start()
                        .chars()
                        .take_while(|c| c.is_ascii() && is_word_byte(*c as u8))
                        .collect();
                    if ITER_METHODS.contains(&meth.as_str())
                        && !allowed(lines, idx, RULE_HASH_ITER)
                    {
                        out.push(violation(
                            rel,
                            idx,
                            RULE_HASH_ITER,
                            format!(
                                "`{name}.{meth}()` iterates a hash collection in a \
                                 render-path module; hash order is nondeterministic — \
                                 use a BTreeMap/sorted-key walk or annotate why the \
                                 order cannot be observed"
                            ),
                        ));
                    }
                }
            }
            // `for .. in map` / `for .. in &map` consume the collection
            // without a method call.
            if let Some(fp) = word_positions(&l.code, "for").first() {
                let tail = &l.code[*fp..];
                if let Some(inp) = word_positions(tail, "in").first() {
                    let expr = tail[inp + 2..].trim();
                    let expr = expr.split('{').next().unwrap_or("").trim();
                    let expr = expr.strip_prefix("&mut ").unwrap_or(expr);
                    let expr = expr.strip_prefix('&').unwrap_or(expr);
                    if expr == name && !allowed(lines, idx, RULE_HASH_ITER) {
                        out.push(violation(
                            rel,
                            idx,
                            RULE_HASH_ITER,
                            format!(
                                "`for .. in {name}` iterates a hash collection in a \
                                 render-path module; hash order is nondeterministic — \
                                 use a BTreeMap/sorted-key walk or annotate why the \
                                 order cannot be observed"
                            ),
                        ));
                    }
                }
            }
        }
    }
}

/// R2: wall-clock reads outside `util/bench.rs`.
fn rule_wall_clock(rel: &str, lines: &[Line], mask: &[bool], out: &mut Vec<Violation>) {
    if rel == "util/bench.rs" || rel.ends_with("/util/bench.rs") {
        return;
    }
    for (idx, l) in lines.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        for pat in ["Instant::now", "SystemTime::now", "SystemTime::UNIX_EPOCH"] {
            let hit = l.code.match_indices(pat).any(|(p, _)| {
                let b = l.code.as_bytes();
                let before_ok = p == 0 || !is_word_byte(b[p - 1]);
                let after = p + pat.len();
                let after_ok = after >= b.len() || !is_word_byte(b[after]);
                before_ok && after_ok
            });
            if hit && !allowed(lines, idx, RULE_WALL_CLOCK) {
                out.push(violation(
                    rel,
                    idx,
                    RULE_WALL_CLOCK,
                    format!(
                        "`{pat}` outside util/bench.rs: wall-clock reads make frame \
                         math timing-dependent — move the measurement behind the \
                         bench runner or annotate the measurement site"
                    ),
                ));
            }
        }
    }
}

/// R3: `unsafe` sites without a `// SAFETY:` comment.
fn rule_missing_safety(rel: &str, lines: &[Line], out: &mut Vec<Violation>) {
    for (idx, l) in lines.iter().enumerate() {
        for p in word_positions(&l.code, "unsafe") {
            let rest = l.code[p + "unsafe".len()..].trim_start();
            let kind = if rest.starts_with("impl") {
                Some("unsafe impl")
            } else if rest.starts_with("fn") {
                Some("unsafe fn")
            } else if rest.starts_with('{') || rest.is_empty() {
                Some("unsafe block")
            } else {
                None
            };
            if let Some(kind) = kind {
                if !has_safety_comment(lines, idx) && !allowed(lines, idx, RULE_MISSING_SAFETY) {
                    out.push(violation(
                        rel,
                        idx,
                        RULE_MISSING_SAFETY,
                        format!(
                            "{kind} without a `// SAFETY:` comment stating the \
                             disjointness/validity argument it relies on"
                        ),
                    ));
                }
            }
        }
    }
}

/// R4: worker-count reads outside `util/par.rs`.
fn rule_thread_count(rel: &str, lines: &[Line], mask: &[bool], out: &mut Vec<Violation>) {
    if rel == "util/par.rs" || rel.ends_with("/util/par.rs") {
        return;
    }
    for (idx, l) in lines.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        for call in ["num_threads", "available_parallelism"] {
            let hit = word_positions(&l.code, call)
                .iter()
                .any(|&p| l.code[p + call.len()..].trim_start().starts_with('('));
            if hit && !allowed(lines, idx, RULE_THREAD_COUNT) {
                out.push(violation(
                    rel,
                    idx,
                    RULE_THREAD_COUNT,
                    format!(
                        "`{call}()` outside util/par.rs: render math must not branch \
                         on worker count — restrict reads to scheduling sites and \
                         annotate them"
                    ),
                ));
            }
        }
    }
}

/// Scan one file's source. `rel` is the path relative to the scan root
/// (used for rule scoping and reporting).
pub fn scan_file(rel: &str, source: &str) -> Vec<Violation> {
    let lines = strip(source);
    let mask = test_mask(&lines);
    let mut out = Vec::new();
    for (idx, l) in lines.iter().enumerate() {
        if !l.comment.contains("detlint:") {
            continue;
        }
        match parse_allow(&l.comment) {
            Some(Err(msg)) => out.push(violation(rel, idx, RULE_BAD_ANNOTATION, msg)),
            Some(Ok(spec)) if !spec.justified => out.push(violation(
                rel,
                idx,
                RULE_BAD_ANNOTATION,
                "suppression lacks a `-- <justification>`".to_string(),
            )),
            _ => {}
        }
    }
    rule_hash_iter(rel, &lines, &mask, &mut out);
    rule_wall_clock(rel, &lines, &mask, &mut out);
    rule_missing_safety(rel, &lines, &mut out);
    rule_thread_count(rel, &lines, &mask, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out.dedup();
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if dir.is_file() {
        if dir.extension().is_some_and(|e| e == "rs") {
            out.push(dir.to_path_buf());
        }
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root` (or `root` itself if a file), in
/// sorted path order — the report itself is deterministic.
pub fn scan_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/")
            .trim_start_matches('/')
            .to_string();
        let src = fs::read_to_string(f)?;
        out.extend(scan_file(&rel, &src));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        strip(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strip_blanks_comments_and_strings() {
        let src = "let a = \"Instant::now\"; // Instant::now\nlet b = 1; /* x */ let c = 2;\n";
        let got = codes(src);
        assert!(!got[0].contains("Instant"), "{got:?}");
        assert_eq!(strip(src)[0].comment.trim(), "Instant::now");
        assert!(got[1].contains("let b = 1;") && got[1].contains("let c = 2;"));
    }

    #[test]
    fn strip_handles_char_literals_and_lifetimes() {
        let got = codes("fn f<'a>(x: &'a str) -> char { '\\n' }\nlet q = 'y';\n");
        assert!(got[0].contains("<'a>"), "lifetime kept as code: {got:?}");
        assert!(!got[1].contains('y'), "char literal blanked: {got:?}");
    }

    #[test]
    fn strip_handles_raw_strings() {
        let got = codes("let p = r#\"unsafe { \"quoted\" }\"#;\nlet n = 3;\n");
        assert!(!got[0].contains("unsafe"), "{got:?}");
        assert!(got[1].contains("let n = 3;"));
    }

    #[test]
    fn strip_handles_nested_block_comments() {
        let got = codes("/* outer /* inner */ still comment */ let x = 1;\n");
        assert!(got[0].contains("let x = 1;"), "{got:?}");
        assert!(!got[0].contains("inner"));
    }

    #[test]
    fn test_mask_covers_cfg_test_module() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let lines = strip(src);
        let mask = test_mask(&lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn allow_annotation_requires_justification_and_known_rule() {
        let ok = parse_allow(" detlint: allow(wall-clock) -- measurement only").unwrap().unwrap();
        assert!(ok.justified && ok.rules == vec!["wall-clock".to_string()]);
        let unjust = parse_allow(" detlint: allow(wall-clock)").unwrap().unwrap();
        assert!(!unjust.justified);
        assert!(parse_allow(" detlint: allow(no-such-rule) -- x").unwrap().is_err());
        assert!(parse_allow(" plain comment").is_none());
    }

    #[test]
    fn hash_idents_found_from_let_field_and_param() {
        let lines = strip(
            "struct S { snapshots: Mutex<HashMap<K, V>> }\n\
             fn f(pos: &HashMap<u32, usize>) {\n\
                 let mut dirty: HashMap<K, V> = HashMap::new();\n\
                 let table = HashSet::new();\n\
             }\n",
        );
        let names = hash_idents(&lines);
        for n in ["snapshots", "pos", "dirty", "table"] {
            assert!(names.iter().any(|x| x == n), "missing {n} in {names:?}");
        }
    }

    #[test]
    fn r1_flags_iteration_not_probes() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &mut HashMap<u32, u32>) -> u32 {\n\
                       let a = m.get(&1).copied().unwrap_or(0);\n\
                       let b: u32 = m.values().sum();\n\
                       let mut c = 0;\n\
                       for (_k, v) in m.iter() {\n\
                           c += v;\n\
                       }\n\
                       a + b + c\n\
                   }\n";
        let v = scan_file("pipeline/x.rs", src);
        let r1: Vec<_> = v.iter().filter(|x| x.rule == RULE_HASH_ITER).collect();
        assert_eq!(r1.len(), 2, "{v:?}");
        assert_eq!(r1[0].line, 4);
        assert_eq!(r1[1].line, 6);
        // Out of the render path the same code is fine.
        assert!(scan_file("util/x.rs", src).is_empty());
    }

    #[test]
    fn r1_flags_for_in_consumption() {
        let src = "fn g() {\n\
                       let mut dirty: HashMap<u32, u32> = HashMap::new();\n\
                       dirty.insert(1, 2);\n\
                       for (k, v) in dirty {\n\
                           drop((k, v));\n\
                       }\n\
                   }\n";
        let v = scan_file("lumina/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn r2_flags_clock_and_accepts_annotation() {
        let src = "fn t() -> f64 {\n\
                       let t0 = Instant::now();\n\
                       t0.elapsed().as_secs_f64()\n\
                   }\n";
        let v = scan_file("coordinator/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, RULE_WALL_CLOCK);
        let annotated = "fn t() -> f64 {\n\
                // detlint: allow(wall-clock) -- reported only, never read back\n\
                let t0 = Instant::now();\n\
                t0.elapsed().as_secs_f64()\n\
            }\n";
        assert!(scan_file("coordinator/x.rs", annotated).is_empty());
        assert!(scan_file("util/bench.rs", src).is_empty(), "bench runner is exempt");
    }

    #[test]
    fn r3_requires_per_site_safety_comments() {
        let src = "unsafe impl Send for P {}\nunsafe impl Sync for P {}\n";
        let v = scan_file("util/x.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        // A shared comment covers only the first impl; each site needs
        // its own adjacent SAFETY block.
        let half = "// SAFETY: disjoint writes\nunsafe impl Send for P {}\nunsafe impl Sync for P {}\n";
        let v = scan_file("util/x.rs", half);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
        let full = "// SAFETY: disjoint writes\nunsafe impl Send for P {}\n\
                    // SAFETY: get() only exposes the pointer value\nunsafe impl Sync for P {}\n";
        assert!(scan_file("util/x.rs", full).is_empty());
    }

    #[test]
    fn r3_covers_blocks_and_same_line_comment() {
        let src = "fn f(p: *mut u32) {\n    unsafe { *p = 1 };\n}\n";
        let v = scan_file("util/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        let ok = "fn f(p: *mut u32) {\n    unsafe { *p = 1 }; // SAFETY: caller owns p\n}\n";
        assert!(scan_file("util/x.rs", ok).is_empty());
        let above = "fn f(p: *mut u32) {\n    // SAFETY: caller owns p\n    unsafe {\n        *p = 1;\n    }\n}\n";
        assert!(scan_file("util/x.rs", above).is_empty());
    }

    #[test]
    fn r4_flags_thread_count_reads_outside_par() {
        let src = "fn s() -> usize {\n    par::num_threads() * 2\n}\n";
        let v = scan_file("pipeline/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, RULE_THREAD_COUNT);
        assert!(scan_file("util/par.rs", src).is_empty(), "par.rs owns the count");
        // `set_num_threads` is a write, not a read.
        assert!(scan_file("pipeline/x.rs", "fn s() { par::set_num_threads(2); }\n").is_empty());
    }

    #[test]
    fn test_modules_are_exempt_from_r1_r2_r4_but_not_r3() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                       fn t() {\n\
                           let t0 = Instant::now();\n\
                           let n = par::num_threads();\n\
                           unsafe { core::hint::unreachable_unchecked() };\n\
                           drop((t0, n));\n\
                       }\n\
                   }\n";
        let v = scan_file("pipeline/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, RULE_MISSING_SAFETY);
    }

    #[test]
    fn unjustified_or_unknown_annotations_are_violations() {
        let src = "fn t() -> f64 {\n\
                // detlint: allow(wall-clock)\n\
                let t0 = Instant::now();\n\
                t0.elapsed().as_secs_f64()\n\
            }\n";
        let v = scan_file("coordinator/x.rs", src);
        assert_eq!(v.len(), 2, "unjustified allow suppresses nothing: {v:?}");
        assert!(v.iter().any(|x| x.rule == RULE_BAD_ANNOTATION));
        assert!(v.iter().any(|x| x.rule == RULE_WALL_CLOCK));
        let unknown = "// detlint: allow(hash-ordering) -- typo'd rule name\nfn t() {}\n";
        let v = scan_file("util/x.rs", unknown);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, RULE_BAD_ANNOTATION);
    }
}
