//! detlint CLI: scan one or more roots (default `src`, i.e. the main
//! crate when run from `rust/`), print violations, exit non-zero if any.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots: Vec<String> = if args.is_empty() { vec!["src".to_string()] } else { args };
    let mut violations = Vec::new();
    for root in &roots {
        match detlint::scan_tree(Path::new(root)) {
            Ok(v) => violations.extend(v),
            Err(e) => {
                eprintln!("detlint: cannot scan `{root}`: {e}");
                return ExitCode::from(2);
            }
        }
    }
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        eprintln!("detlint: clean ({} root(s))", roots.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}
