//! Clean fixture: every pattern detlint accepts, in one render-path file.
use std::collections::{BTreeMap, HashMap};

pub struct Hub {
    snapshots: HashMap<u32, u64>,
}

impl Hub {
    pub fn lookup(&self, k: u32) -> u64 {
        // Probe-only hash access is deterministic.
        self.snapshots.get(&k).copied().unwrap_or(0)
    }

    pub fn merge(&self, dirty: BTreeMap<u32, u64>) -> Vec<u64> {
        // BTreeMap iterates in key order: deterministic, unflagged.
        dirty.into_iter().map(|(_, e)| e).collect()
    }

    pub fn drain_sorted(&mut self) -> Vec<(u32, u64)> {
        // detlint: allow(hash-order-iter) -- drained pairs are sorted by key before use
        let mut v: Vec<(u32, u64)> = self.snapshots.drain().collect();
        v.sort_unstable();
        v
    }
}

pub struct Cursor(*mut f32);

// SAFETY: Cursor is only constructed over segments proven disjoint by
// the exclusive prefix-sum; no two holders alias.
unsafe impl Send for Cursor {}

pub fn scatter(c: &Cursor, v: f32) {
    // SAFETY: the caller's segment claim makes this write exclusive.
    unsafe {
        *c.0 = v;
    }
}

pub fn report_elapsed() -> f64 {
    // detlint: allow(wall-clock) -- report-only timing, printed and discarded
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn pool_size() -> usize {
    // detlint: allow(thread-count) -- scheduling only: sizes the worker pool, never frame math
    par::num_threads()
}

#[cfg(test)]
mod tests {
    // Test modules may read clocks and thread counts freely.
    #[test]
    fn timing_in_tests_is_exempt() {
        let t0 = std::time::Instant::now();
        let n = par::num_threads();
        assert!(t0.elapsed().as_secs() < 60 || n > 0);
    }
}
