//! Seeded R1 fixture: order-dependent hash iteration on the render path.
use std::collections::HashMap;

pub fn merge(dirty: HashMap<u32, u64>, out: &mut Vec<u64>) {
    // Violation: `.iter()` observes hash order.
    for (_geom, epoch) in dirty.iter() {
        out.push(*epoch);
    }
}

pub fn publish(mut dirty: HashMap<u32, u64>) -> Vec<u32> {
    // Violation: `.keys()` observes hash order.
    let ks: Vec<u32> = dirty.keys().copied().collect();
    // Violation: bare `for .. in map` consumes in hash order.
    for (k, _v) in dirty {
        let _ = k;
    }
    ks
}

pub fn probe_is_fine(dirty: &HashMap<u32, u64>) -> u64 {
    // Probes don't observe order: unflagged.
    dirty.get(&7).copied().unwrap_or(0)
}
