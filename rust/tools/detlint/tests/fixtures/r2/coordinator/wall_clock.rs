//! Seeded R2 fixture: wall-clock read outside util/bench.rs.
use std::time::Instant;

pub fn frame_budget_ms() -> f64 {
    // Violation: clock read in frame math, no annotation.
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64() * 1e3
}

pub fn annotated_report_site() -> f64 {
    // detlint: allow(wall-clock) -- report-only measurement, value never feeds frame math
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
