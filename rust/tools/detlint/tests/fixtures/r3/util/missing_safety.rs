//! Seeded R3 fixture: unsafe sites without SAFETY comments.

pub struct RawSlot(pub *mut u32);

// Violation: unsafe impl with no SAFETY comment.
unsafe impl Send for RawSlot {}

pub fn write(slot: &RawSlot, v: u32) {
    // Violation: unsafe block with no SAFETY comment.
    unsafe {
        *slot.0 = v;
    }
}

pub fn write_documented(slot: &RawSlot, v: u32) {
    // SAFETY: caller guarantees slot.0 points at a live, exclusively
    // owned u32 for the duration of the call.
    unsafe {
        *slot.0 = v;
    }
}
