//! Seeded R4 fixture: worker-count read outside util/par.rs.

pub fn tile_batch(total: usize) -> usize {
    // Violation: render math branching on worker count.
    total / par::num_threads().max(1)
}

pub fn set_is_fine() {
    // A write is configuration, not a read: unflagged.
    par::set_num_threads(2);
}
