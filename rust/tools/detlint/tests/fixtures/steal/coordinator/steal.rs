//! Seeded scheduler fixture: the stealing scheduler's worker-pool
//! sizing read is the one justified thread-count site outside
//! util/par.rs; the same read without its annotation trips R4.

pub fn worker_pool_size(tasks: usize) -> usize {
    // detlint: allow(thread-count) -- scheduling site: sizes the claiming worker pool; task outputs are thread-budget invariant
    let total = par::num_threads();
    total.min(tasks).max(1)
}

pub fn bad_chunking(tasks: usize) -> usize {
    // Violation: the same read feeding chunk math, no justification.
    tasks.div_ceil(par::num_threads().max(1))
}
