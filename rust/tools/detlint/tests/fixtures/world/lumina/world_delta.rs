//! World-cache delta fixture: the render path probes its hash overlay
//! point-wise (clean); the epoch merge must walk the insertion-ordered
//! log, not the hash table — the seeded drain is the one violation.
use std::collections::{BTreeMap, HashMap, HashSet};

pub struct WorldDelta {
    overlay: HashMap<u64, [f32; 3]>,
    touched: Vec<u64>,
    touched_set: HashSet<u64>,
}

impl WorldDelta {
    pub fn lookup(&self, key: u64) -> Option<[f32; 3]> {
        // Probe-only access never observes hash order: unflagged.
        self.overlay.get(&key).copied()
    }

    pub fn touch(&mut self, key: u64) {
        // `insert` is a probe too: no order observed.
        if self.touched_set.insert(key) {
            self.touched.push(key);
        }
    }

    pub fn merge_wrong(&mut self, table: &mut BTreeMap<u64, [f32; 3]>) {
        // Violation: draining the overlay observes hash order.
        for (k, v) in self.overlay.drain() {
            table.insert(k, v);
        }
    }

    pub fn merge_right(&self, table: &mut BTreeMap<u64, [f32; 3]>) {
        // The house pattern: replay the insertion-ordered touch log and
        // probe the overlay per key — bitwise stable at any thread count.
        for &k in &self.touched {
            if let Some(v) = self.overlay.get(&k) {
                table.insert(k, *v);
            }
        }
    }
}
