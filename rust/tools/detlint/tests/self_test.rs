//! detlint self-tests: each seeded fixture trips exactly its rule (and
//! the binary exits non-zero on it), the clean fixture and the real
//! `rust/src` tree scan clean, and annotated suppressions hold.

use std::path::{Path, PathBuf};
use std::process::Command;

use detlint::{
    scan_tree, Violation, RULE_HASH_ITER, RULE_MISSING_SAFETY, RULE_THREAD_COUNT, RULE_WALL_CLOCK,
};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn scan_fixture(name: &str) -> Vec<Violation> {
    scan_tree(&fixture(name)).expect("fixture tree scans")
}

fn render(vs: &[Violation]) -> String {
    vs.iter().map(|v| format!("  {v}\n")).collect()
}

#[test]
fn r1_fixture_trips_hash_order_iter_only() {
    let vs = scan_fixture("r1");
    assert!(!vs.is_empty(), "r1 fixture must trip");
    assert!(
        vs.iter().all(|v| v.rule == RULE_HASH_ITER),
        "unexpected rules:\n{}",
        render(&vs)
    );
    let lines: Vec<usize> = vs.iter().map(|v| v.line).collect();
    assert_eq!(lines, vec![6, 13, 15], "seeded sites:\n{}", render(&vs));
}

#[test]
fn r2_fixture_trips_wall_clock_only() {
    let vs = scan_fixture("r2");
    assert_eq!(vs.len(), 1, "one unannotated clock read:\n{}", render(&vs));
    assert_eq!(vs[0].rule, RULE_WALL_CLOCK);
    assert_eq!(vs[0].line, 6);
}

#[test]
fn r3_fixture_trips_missing_safety_only() {
    let vs = scan_fixture("r3");
    assert_eq!(vs.len(), 2, "impl + block both lack SAFETY:\n{}", render(&vs));
    assert!(vs.iter().all(|v| v.rule == RULE_MISSING_SAFETY));
    assert_eq!(vs[0].line, 6, "unsafe impl site");
    assert_eq!(vs[1].line, 10, "unsafe block site");
}

#[test]
fn r4_fixture_trips_thread_count_only() {
    let vs = scan_fixture("r4");
    assert_eq!(vs.len(), 1, "one worker-count read:\n{}", render(&vs));
    assert_eq!(vs[0].rule, RULE_THREAD_COUNT);
    assert_eq!(vs[0].line, 5);
}

/// The stealing scheduler's claiming site (`coordinator::steal::
/// run_round`) is the one sanctioned thread-count read outside
/// `util/par.rs`: annotated as a scheduling site it scans clean, and
/// the identical read without the annotation still trips R4.
#[test]
fn steal_fixture_allows_scheduler_site_and_trips_unannotated_read() {
    let vs = scan_fixture("steal");
    assert_eq!(vs.len(), 1, "only the unannotated read trips:\n{}", render(&vs));
    assert_eq!(vs[0].rule, RULE_THREAD_COUNT);
    assert_eq!(vs[0].line, 13, "the annotated claiming site above scans clean");
}

/// The world-cache delta pattern (PR 10): point probes on the hash
/// overlay and the insertion-ordered log replay scan clean; only the
/// seeded hash-order drain in the merge trips R1.
#[test]
fn world_fixture_probes_clean_and_trips_only_the_merge_drain() {
    let vs = scan_fixture("world");
    assert_eq!(vs.len(), 1, "only the drain trips:\n{}", render(&vs));
    assert_eq!(vs[0].rule, RULE_HASH_ITER);
    assert_eq!(vs[0].line, 27, "the seeded hash-order drain in merge_wrong");
}

#[test]
fn clean_fixture_scans_clean() {
    let vs = scan_fixture("clean");
    assert!(vs.is_empty(), "clean fixture must not trip:\n{}", render(&vs));
}

#[test]
fn binary_exits_nonzero_on_each_seeded_fixture() {
    for name in ["r1", "r2", "r3", "r4", "steal", "world"] {
        let out = Command::new(env!("CARGO_BIN_EXE_detlint"))
            .arg(fixture(name))
            .output()
            .expect("run detlint");
        assert_eq!(
            out.status.code(),
            Some(1),
            "fixture {name}: stdout:\n{}stderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

#[test]
fn binary_exits_zero_on_clean_fixture() {
    let out = Command::new(env!("CARGO_BIN_EXE_detlint"))
        .arg(fixture("clean"))
        .output()
        .expect("run detlint");
    assert!(
        out.status.success(),
        "stdout:\n{}stderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn binary_exits_two_on_missing_root() {
    let out = Command::new(env!("CARGO_BIN_EXE_detlint"))
        .arg(fixture("no-such-dir"))
        .output()
        .expect("run detlint");
    assert_eq!(out.status.code(), Some(2));
}

/// The acceptance criterion: the real crate scans clean, meaning every
/// remaining suppression in `rust/src` carries a written justification.
#[test]
fn rust_src_scans_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let vs = scan_tree(&src).expect("rust/src scans");
    assert!(vs.is_empty(), "rust/src must be detlint-clean:\n{}", render(&vs));
}
